//! Typed configuration schemas, populated from [`super::parse_config`]
//! documents (or built programmatically by the examples/benches).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::parser::ConfigValue;
use crate::adios::EngineKind;
use crate::adios::sst::{QueueConfig, QueueFullPolicy};

/// One stage of a loosely-coupled pipeline (Fig. 2): a producer, an
/// adaptor (`openpmd-pipe`), an analysis, or a sink.
#[derive(Clone, Debug, PartialEq)]
pub struct StageConfig {
    pub name: String,
    /// `"producer" | "pipe" | "analysis" | "file-sink"`.
    pub kind: String,
    /// Engine on the *input* side (readers); producers have none.
    pub input: Option<EngineKind>,
    /// Engine on the *output* side (writers); sinks may write files.
    pub output: Option<EngineKind>,
    /// Parallel instances per node.
    pub instances_per_node: usize,
}

/// A full pipeline description.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub name: String,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub stages: Vec<StageConfig>,
    pub queue: QueueConfig,
    /// Chunk-distribution strategy name (resolved by
    /// `distribution::by_name`).
    pub strategy: String,
    /// Simulation steps between output attempts (paper: 100 / 2000 / 400).
    pub output_period: usize,
    /// Bytes produced per writer rank per output step.
    pub bytes_per_rank: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            name: "pipeline".into(),
            nodes: 1,
            gpus_per_node: 6,
            stages: Vec::new(),
            queue: QueueConfig::default(),
            strategy: "hyperslabs".into(),
            output_period: 100,
            bytes_per_rank: 0,
        }
    }
}

impl PipelineConfig {
    /// Build from a parsed config map.
    pub fn from_map(map: &BTreeMap<String, ConfigValue>) -> Result<Self> {
        let mut cfg = PipelineConfig::default();
        if let Some(v) = map.get("name") {
            cfg.name = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("name must be a string"))?
                .to_string();
        }
        if let Some(v) = map.get("nodes") {
            cfg.nodes = v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("nodes must be a non-negative integer"))?;
        }
        if let Some(v) = map.get("gpus_per_node") {
            cfg.gpus_per_node = v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("gpus_per_node must be an integer"))?;
        }
        if let Some(v) = map.get("strategy") {
            cfg.strategy = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("strategy must be a string"))?
                .to_string();
        }
        if let Some(v) = map.get("output_period") {
            cfg.output_period = v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("output_period must be an integer"))?;
        }
        if let Some(v) = map.get("bytes_per_rank") {
            cfg.bytes_per_rank = match v {
                ConfigValue::Int(i) if *i >= 0 => *i as u64,
                ConfigValue::Str(s) => crate::util::bytes::parse_bytes(s)
                    .map_err(|e| anyhow::anyhow!(e))?,
                _ => bail!("bytes_per_rank must be an integer or size string"),
            };
        }
        if let Some(v) = map.get("queue.policy") {
            cfg.queue.policy = match v.as_str() {
                Some("discard") => QueueFullPolicy::Discard,
                Some("block") => QueueFullPolicy::Block,
                other => bail!("queue.policy must be discard|block, got {other:?}"),
            };
        }
        if let Some(v) = map.get("queue.limit") {
            cfg.queue.limit = v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("queue.limit must be an integer"))?;
        }
        // Stages: stage.<n>.* keys, n = 0, 1, 2, ...
        let mut stage_idx = 0usize;
        loop {
            let prefix = format!("stage.{stage_idx}.");
            let keys: Vec<&String> =
                map.keys().filter(|k| k.starts_with(&prefix)).collect();
            if keys.is_empty() {
                break;
            }
            let get_str = |field: &str| -> Option<&str> {
                map.get(&format!("{prefix}{field}"))
                    .and_then(|v| v.as_str())
            };
            let kind = get_str("kind")
                .ok_or_else(|| anyhow::anyhow!("stage {stage_idx} missing kind"))?
                .to_string();
            let stage = StageConfig {
                name: get_str("name")
                    .unwrap_or(kind.as_str())
                    .to_string(),
                kind,
                input: get_str("input")
                    .map(EngineKind::parse)
                    .transpose()?,
                output: get_str("output")
                    .map(EngineKind::parse)
                    .transpose()?,
                instances_per_node: map
                    .get(&format!("{prefix}instances_per_node"))
                    .map(|v| {
                        v.as_usize().ok_or_else(|| {
                            anyhow::anyhow!("instances_per_node must be an integer")
                        })
                    })
                    .transpose()?
                    .unwrap_or(1),
            };
            cfg.stages.push(stage);
            stage_idx += 1;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural validation.
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            bail!("pipeline needs at least one node");
        }
        if self.gpus_per_node == 0 {
            bail!("gpus_per_node must be positive");
        }
        if self.queue.limit == 0 {
            bail!("queue.limit must be positive");
        }
        let per_node: usize = self
            .stages
            .iter()
            .filter(|s| s.kind == "producer" || s.kind == "analysis")
            .map(|s| s.instances_per_node)
            .sum();
        if per_node > self.gpus_per_node {
            bail!(
                "stages place {per_node} GPU ranks per node but nodes have \
                 {} GPUs",
                self.gpus_per_node
            );
        }
        for s in &self.stages {
            match s.kind.as_str() {
                "producer" => {
                    if s.output.is_none() {
                        bail!("producer stage {} needs an output engine",
                              s.name);
                    }
                }
                "pipe" | "analysis" => {
                    if s.input.is_none() {
                        bail!("{} stage {} needs an input engine",
                              s.kind, s.name);
                    }
                }
                "file-sink" => {}
                other => bail!("unknown stage kind {other:?}"),
            }
        }
        Ok(())
    }
}

/// Parameters of one simulated benchmark run (Figs. 6–9).
#[derive(Clone, Debug)]
pub struct BenchmarkConfig {
    pub nodes: Vec<usize>,
    pub repetitions: usize,
    pub duration_s: f64,
    pub seed: u64,
}

impl Default for BenchmarkConfig {
    fn default() -> Self {
        BenchmarkConfig {
            nodes: vec![64, 128, 256, 512],
            repetitions: 3,
            duration_s: 900.0, // the paper's 15 minutes
            seed: 0x06e6_50d5_7ea4_2021, // "openPMD-stream 2021"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_config;

    fn sample() -> BTreeMap<String, ConfigValue> {
        parse_config(
            r#"
            name = "sst-bp"
            nodes = 4
            gpus_per_node = 6
            strategy = "hostname"
            output_period = 100
            bytes_per_rank = "9.14 GiB"

            [queue]
            policy = "discard"
            limit = 2

            [stage.0]
            kind = "producer"
            name = "picongpu"
            output = "sst:inproc"
            instances_per_node = 6

            [stage.1]
            kind = "pipe"
            name = "openpmd-pipe"
            input = "sst:inproc"
            output = "bp:1"
            instances_per_node = 1
            "#,
        )
        .unwrap()
    }

    #[test]
    fn full_pipeline_parses() {
        let cfg = PipelineConfig::from_map(&sample()).unwrap();
        assert_eq!(cfg.name, "sst-bp");
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.stages.len(), 2);
        assert_eq!(cfg.stages[0].instances_per_node, 6);
        assert_eq!(cfg.stages[1].output,
                   Some(EngineKind::Bp { aggregation: 1 }));
        assert_eq!(cfg.queue.policy, QueueFullPolicy::Discard);
        assert_eq!(cfg.bytes_per_rank,
                   crate::util::bytes::parse_bytes("9.14 GiB").unwrap());
    }

    #[test]
    fn oversubscribed_gpus_rejected() {
        let mut map = sample();
        map.insert("stage.0.instances_per_node".into(),
                   ConfigValue::Int(7));
        assert!(PipelineConfig::from_map(&map).is_err());
    }

    #[test]
    fn producer_without_output_rejected() {
        let mut map = sample();
        map.remove("stage.0.output");
        assert!(PipelineConfig::from_map(&map).is_err());
    }

    #[test]
    fn bad_policy_rejected() {
        let mut map = sample();
        map.insert("queue.policy".into(),
                   ConfigValue::Str("yolo".into()));
        assert!(PipelineConfig::from_map(&map).is_err());
    }

    #[test]
    fn defaults_are_sane() {
        let b = BenchmarkConfig::default();
        assert_eq!(b.nodes, vec![64, 128, 256, 512]);
        assert_eq!(b.repetitions, 3);
        assert_eq!(b.duration_s, 900.0);
    }
}
