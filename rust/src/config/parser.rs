//! TOML-subset parser.
//!
//! Supported: `[section]` / `[section.sub]` headers, `key = value` with
//! strings, integers, floats, booleans, and homogeneous inline arrays;
//! `#` comments. Unsupported (by design): dotted keys, arrays of tables,
//! multi-line strings, dates. Errors carry line numbers.

use std::collections::BTreeMap;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<ConfigValue>),
}

impl ConfigValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            ConfigValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            ConfigValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            ConfigValue::Float(f) => Some(*f),
            ConfigValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            ConfigValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[ConfigValue]> {
        match self {
            ConfigValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with location.
#[derive(Debug, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line,
               self.message)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, message: impl Into<String>) -> ParseError {
    ParseError { line, message: message.into() }
}

/// Parse a config document into `section.key -> value` (keys in the
/// top-level section have no prefix).
pub fn parse_config(input: &str)
    -> Result<BTreeMap<String, ConfigValue>, ParseError>
{
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (idx, raw) in input.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            if !name.chars().all(|c| {
                c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.'
            }) {
                return Err(err(lineno, format!("bad section name {name:?}")));
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, "expected key = value"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(value.trim(), lineno)?;
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if out.contains_key(&full_key) {
            return Err(err(lineno, format!("duplicate key {full_key:?}")));
        }
        out.insert(full_key, value);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<ConfigValue, ParseError> {
    if s.is_empty() {
        return Err(err(line, "empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(line, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(line, "embedded quote in string (unsupported)"));
        }
        return Ok(ConfigValue::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(ConfigValue::Array(Vec::new()));
        }
        let items = split_array_items(inner, line)?;
        let parsed: Result<Vec<_>, _> = items
            .into_iter()
            .map(|item| parse_value(item.trim(), line))
            .collect();
        return Ok(ConfigValue::Array(parsed?));
    }
    match s {
        "true" => return Ok(ConfigValue::Bool(true)),
        "false" => return Ok(ConfigValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(ConfigValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(ConfigValue::Float(f));
    }
    Err(err(line, format!("cannot parse value {s:?}")))
}

/// Split a flat array body on commas that are not inside strings.
fn split_array_items(s: &str, line: usize)
    -> Result<Vec<&str>, ParseError>
{
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    let mut depth = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err(err(line, "unterminated string in array"));
    }
    items.push(&s[start..]);
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sectioned() {
        let doc = r#"
            # pipeline definition
            name = "kh-pipeline"   # inline comment
            nodes = 64

            [sst]
            transport = "tcp"
            queue_limit = 2
            discard = true

            [producer.species]
            weights = [1.0, 2.0, 3.5]
            labels = ["x", "y"]
        "#;
        let c = parse_config(doc).unwrap();
        assert_eq!(c["name"].as_str(), Some("kh-pipeline"));
        assert_eq!(c["nodes"].as_int(), Some(64));
        assert_eq!(c["sst.transport"].as_str(), Some("tcp"));
        assert_eq!(c["sst.discard"].as_bool(), Some(true));
        assert_eq!(
            c["producer.species.weights"].as_array().unwrap().len(),
            3
        );
        assert_eq!(
            c["producer.species.labels"].as_array().unwrap()[1].as_str(),
            Some("y")
        );
    }

    #[test]
    fn numbers_and_underscores() {
        let c = parse_config("big = 1_000_000\npi = 3.14\nneg = -7").unwrap();
        assert_eq!(c["big"].as_int(), Some(1_000_000));
        assert_eq!(c["pi"].as_float(), Some(3.14));
        assert_eq!(c["neg"].as_int(), Some(-7));
    }

    #[test]
    fn int_coerces_to_float() {
        let c = parse_config("x = 5").unwrap();
        assert_eq!(c["x"].as_float(), Some(5.0));
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = parse_config(r##"tag = "a#b""##).unwrap();
        assert_eq!(c["tag"].as_str(), Some("a#b"));
    }

    #[test]
    fn error_line_numbers() {
        let e = parse_config("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_config("x = \"unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse_config("a = 1\na = 2").is_err());
        // Same key in different sections is fine.
        assert!(parse_config("[s1]\na = 1\n[s2]\na = 2").is_ok());
    }

    #[test]
    fn empty_array_and_nested_rejected_gracefully() {
        let c = parse_config("xs = []").unwrap();
        assert_eq!(c["xs"].as_array().unwrap().len(), 0);
        let c = parse_config("xs = [[1, 2], [3]]").unwrap();
        let outer = c["xs"].as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_array().unwrap().len(), 2);
    }

    #[test]
    fn bad_sections_rejected() {
        assert!(parse_config("[unclosed").is_err());
        assert!(parse_config("[]").is_err());
        assert!(parse_config("[has space]").is_err());
    }
}
