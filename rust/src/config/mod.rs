//! Configuration system (S15): a TOML-subset parser plus typed schemas
//! for pipelines and benchmarks.
//!
//! The paper's *flexibility* criterion: "optimization for system
//! specifics should be exposed through runtime configuration". Engine
//! kind, transport, queue policy, distribution strategy and node layout
//! are all config values here — application code never changes.

mod parser;
mod schema;

pub use parser::{parse_config, ConfigValue, ParseError};
pub use schema::{BenchmarkConfig, PipelineConfig, StageConfig};
