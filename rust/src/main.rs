//! `openpmd-stream` — the launcher.
//!
//! Subcommands:
//!
//! * `pipe`      — run the `openpmd-pipe` adaptor (the paper's §4.1
//!                 tool): any engine in, any engine out.
//! * `serve`     — run the streaming fan-out daemon: subscribe once to
//!                 any input spec, stage each step's encoded chunks in
//!                 a bounded cache, and serve N dynamically joining
//!                 SST subscribers.
//! * `produce`   — run the Kelvin–Helmholtz producer, writing openPMD
//!                 steps to a BP file, JSON dir or SST stream.
//! * `analyze`   — run the SAXS consumer over any input spec.
//! * `validate`  — check a series for openPMD conformance.
//! * `info`      — dump variables/attributes/chunks of a series.
//! * `systems`   — print the Table 1 system comparison.
//!
//! Every mode resolves its endpoints through the typed spec grammar
//! ([`SourceSpec`] / [`SinkSpec`]) — `main.rs` contains no engine
//! string matching of its own, and the shared pipeline knobs parse
//! once through [`CommonOptions::from_args`].
//!
//! The end-to-end streaming setups live in `examples/` (multi-threaded
//! in one process so they are runnable without a job scheduler); this
//! binary provides the single-role building blocks that `examples/`
//! compose, usable across real processes via the TCP transport.

use anyhow::{bail, Context, Result};

use openpmd_stream::adios::engine::{cast, Engine, StepStatus};
use openpmd_stream::adios::ops::OpChain;
use openpmd_stream::adios::spec::{ReaderSlot, SinkSpec, SourceSpec};
use openpmd_stream::analysis::SaxsAnalyzer;
use openpmd_stream::bench::Table;
use openpmd_stream::obs;
use openpmd_stream::cluster::systems;
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::series::{self, Series};
use openpmd_stream::openpmd::validate;
use openpmd_stream::pipeline::fleet::run_fleet;
use openpmd_stream::pipeline::pipe::{run, MetricsSink};
use openpmd_stream::pipeline::serve::{LagPolicy, ServeDaemon};
use openpmd_stream::pipeline::{ops_summary, CommonOptions};
use openpmd_stream::producer::KhProducer;
use openpmd_stream::runtime::Runtime;
use openpmd_stream::util::bytes::fmt_bytes;
use openpmd_stream::util::cli::{render_help, Args, OptSpec};

fn main() {
    openpmd_stream::util::logging::init_from_env();
    let args = match Args::from_env(true) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("pipe") => cmd_pipe(&args),
        Some("serve") => cmd_serve(&args),
        Some("produce") => cmd_produce(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("validate") => cmd_validate(&args),
        Some("info") => cmd_info(&args),
        Some("systems") => cmd_systems(),
        Some("help") | None => {
            print!("{}", help());
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n\n{}", help());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn help() -> String {
    render_help(
        "openpmd-stream",
        "streaming data pipelines with openPMD + ADIOS2 (paper reproduction)",
        "openpmd-stream <pipe|serve|produce|analyze|validate|info|systems> \
         [OPTIONS]",
        &[
            OptSpec { name: "in", value_name: Some("SPEC"),
                      default: None,
                      help: "input: a BP file, a JSON step directory, \
                             sst+ADDR[,ADDR...] (subscribe to N SST \
                             writers), serve+ADDR (subscribe to a \
                             serve fan-out daemon), \
                             shards:<out>.index.json (reassemble a \
                             reader fleet's shard family as ONE \
                             logical series), or merge:a,b,... \
                             (multiplex series sources, backends mixed \
                             freely)" },
            OptSpec { name: "out", value_name: Some("SPEC"),
                      default: None,
                      help: "output: bp:PATH (or a bare path), \
                             json:PATH, sst+ADDR (stage steps for SST \
                             subscribers; tcp://host:port selects \
                             TCP), or serve+ADDR (the serve daemon's \
                             downstream listen endpoint)" },
            OptSpec { name: "engine", value_name: Some("bp|json|sst[:tcp]"),
                      default: None,
                      help: "legacy output engine kind paired with a \
                             plain --out path; prefer the typed --out \
                             spec prefixes" },
            OptSpec { name: "steps", value_name: Some("N"),
                      default: Some("10"), help: "steps to produce/process" },
            OptSpec { name: "pipeline-depth", value_name: Some("N"),
                      default: Some("0"),
                      help: "staged read-ahead steps (0 = serial; \
                             2 = double buffering: store step N while \
                             loading step N+1); with --readers M > 1 \
                             each fleet worker gets its own staged \
                             fetch thread" },
            OptSpec { name: "readers", value_name: Some("M"),
                      default: Some("1"),
                      help: "pipe: parallel reader-fleet width; M > 1 \
                             runs M workers over a shared per-step \
                             chunk plan, each writing its own output \
                             shard (out.r0ofM.bp ...) plus a merged \
                             series index" },
            OptSpec { name: "strategy", value_name: Some("NAME"),
                      default: Some("roundrobin"),
                      help: "pipe: chunk-distribution strategy for the \
                             fleet (roundrobin|hyperslabs|binpacking|\
                             loadbalanced|hostname[:2nd:fallback])" },
            OptSpec { name: "operators", value_name: Some("CHAIN"),
                      default: None,
                      help: "per-variable operator chain, e.g. \
                             shuffle|rle or zfp:14|shuffle|rle \
                             (produce: applied to every record; \
                             pipe/serve: re-encode forwarded variables \
                             with this chain)" },
            OptSpec { name: "cache-steps", value_name: Some("K"),
                      default: Some("4"),
                      help: "serve: staged steps kept addressable (the \
                             fan-out cache depth; late joiners start \
                             at the cache tail)" },
            OptSpec { name: "lag-policy", value_name: Some("drop|block"),
                      default: Some("drop"),
                      help: "serve: slow-subscriber policy at cache \
                             eviction — drop evicts anyway (laggards \
                             skip the step), block backpressures the \
                             upstream until every subscriber finished \
                             it" },
            OptSpec { name: "period", value_name: Some("N"),
                      default: Some("10"), help: "sim steps between outputs" },
            OptSpec { name: "particles", value_name: Some("N"),
                      default: Some("100000"), help: "particles (produce)" },
            OptSpec { name: "no-runtime", value_name: None, default: None,
                      help: "skip PJRT artifacts (pure-rust fallback)" },
            OptSpec { name: "q-max", value_name: Some("Q"),
                      default: Some("2.0"), help: "max |q| (analyze)" },
            OptSpec { name: "csv", value_name: Some("PATH"),
                      default: Some("scatter.csv"),
                      help: "scatter-plot output (analyze)" },
            OptSpec { name: "trace", value_name: Some("PATH"),
                      default: None,
                      help: "pipe/serve/produce: record per-step spans \
                             and write a Chrome trace-event file on \
                             exit (load in Perfetto; a .jsonl path \
                             writes JSON lines instead)" },
            OptSpec { name: "metrics", value_name: Some("PATH"),
                      default: None,
                      help: "pipe/serve/produce: append JSON-line \
                             counter snapshots to PATH while running" },
            OptSpec { name: "metrics-interval", value_name: Some("N"),
                      default: Some("1"),
                      help: "steps between --metrics lines" },
        ],
    )
}

/// Parse the observability flags shared by `pipe`, `serve` and
/// `produce`: `--trace` switches the tracing layer on (near-zero cost
/// when off) and names the export file; `--metrics
/// [--metrics-interval N]` builds the periodic counter-snapshot sink.
fn obs_from_args(
    args: &Args,
) -> Result<(Option<std::path::PathBuf>, Option<MetricsSink>)> {
    let trace_path = args.get("trace").map(std::path::PathBuf::from);
    if trace_path.is_some() {
        obs::trace::enable();
    }
    let every: u64 = args.get_parse_or("metrics-interval", 1)?;
    if every == 0 {
        bail!("--metrics-interval must be >= 1");
    }
    let sink = args.get("metrics").map(|p| MetricsSink {
        path: std::path::PathBuf::from(p),
        every,
    });
    Ok((trace_path, sink))
}

/// Drain the span collector into `path`: a Chrome trace-event document
/// (Perfetto-loadable), or JSON lines when the path ends in `.jsonl`.
fn write_trace_file(path: &std::path::Path) -> Result<()> {
    if path.extension().and_then(|e| e.to_str()) == Some("jsonl") {
        let dumps = obs::trace::drain();
        std::fs::write(path, obs::export::trace_json_lines(&dumps))
            .with_context(|| format!("writing {}", path.display()))?;
    } else {
        obs::export::write_chrome_trace(path)
            .with_context(|| format!("writing {}", path.display()))?;
    }
    eprintln!("trace written to {}", path.display());
    Ok(())
}

fn parse_operators(args: &Args) -> Result<Option<OpChain>> {
    match args.get("operators") {
        None => Ok(None),
        Some(spec) => OpChain::parse(spec)
            .map(Some)
            .map_err(|e| anyhow::anyhow!("--operators: {e}")),
    }
}

/// Resolve `--out` (and the legacy `--engine` pairing) into a typed
/// sink: an explicit `--engine KIND` interprets `--out` as a plain
/// path/address the historic way; otherwise `--out` is a full
/// [`SinkSpec`] (where a bare path still means a BP file).
fn sink_from_args(args: &Args, out: &str) -> Result<SinkSpec> {
    Ok(match args.get("engine") {
        Some(kind) => SinkSpec::from_parts(kind, out)?,
        None => SinkSpec::parse(out)?,
    })
}

fn cmd_pipe(args: &Args) -> Result<()> {
    args.reject_unknown(&["in", "out", "engine", "steps",
                          "pipeline-depth", "operators", "readers",
                          "strategy", "trace", "metrics",
                          "metrics-interval"])?;
    let input = args.get("in").context("--in required")?;
    let output = args.get("out").context("--out required")?;
    let readers: usize = args.get_parse_or("readers", 1)?;
    let (trace_path, metrics_sink) = obs_from_args(args)?;
    let source = SourceSpec::parse(input)?;
    let sink = sink_from_args(args, output)?;
    let common = CommonOptions::from_args(args)?.metrics(metrics_sink);

    if readers == 1 {
        let slot = ReaderSlot::solo();
        let mut reader = source
            .open(slot)
            .with_context(|| format!("opening pipe input {source}"))?;
        let mut writer = sink
            .open_writer(slot)
            .with_context(|| format!("opening pipe output {sink}"))?;
        let depth = common.depth;
        let report = run(reader.as_mut(), writer.as_mut(), common.pipe())?;
        println!(
            "piped {} steps ({} dropped), {} in, {} out, {} chunks",
            report.steps,
            report.dropped_steps,
            fmt_bytes(report.bytes_in),
            fmt_bytes(report.bytes_out),
            report.chunks
        );
        if !report.ops.is_empty() {
            println!("{}", ops_summary(&report.ops));
        }
        if depth > 0 {
            let o = &report.overlap;
            println!(
                "staged depth {depth}: wall {:.3}s vs serial load+store \
                 {:.3}s — {:.3}s hidden ({:.0}% of the cheaper stage)",
                o.wall_seconds,
                o.serial_estimate(),
                o.hidden_seconds(),
                100.0 * o.overlap_efficiency()
            );
        }
        if let Some(p) = &trace_path {
            write_trace_file(p)?;
        }
        return Ok(());
    }

    // Parallel fleet: M workers, each with its own reader subscribed
    // to all writers and its own output shard. `--pipeline-depth N`
    // additionally gives every worker staged read-ahead, so per-worker
    // load/store latencies overlap on top of the fleet parallelism.
    let mut inputs = Vec::with_capacity(readers);
    let mut outputs = Vec::with_capacity(readers);
    for rank in 0..readers {
        let slot = ReaderSlot::of(rank, readers)?;
        inputs.push(source.open(slot).with_context(|| {
            format!("opening pipe input {source} for rank {rank}")
        })?);
        outputs.push(sink.open_writer(slot).with_context(|| {
            format!("opening pipe output {sink} for rank {rank}")
        })?);
    }
    let report = run_fleet(inputs, outputs, common.fleet(readers)?)?;
    println!("{}", report.summary());
    for r in &report.per_rank {
        println!(
            "  rank {}: {} steps, {} in, {} out, {} chunks, busy {:.3}s",
            r.rank,
            r.steps,
            fmt_bytes(r.bytes_in),
            fmt_bytes(r.bytes_out),
            r.chunks,
            r.busy_seconds
        );
    }
    if !report.ops.is_empty() {
        println!("{}", ops_summary(&report.ops));
    }
    let index = series::write_shard_index(output, readers,
                                          report.steps())?;
    println!("shard index: {}", index.display());
    // Fleet workers write their own shards concurrently, so per-step
    // metric lines would interleave; the fleet emits one final
    // whole-process snapshot instead.
    if let Some(sink) = &common.metrics_sink {
        let line = obs::export::metrics_line(
            None, &obs::metrics::snapshot_metrics());
        std::fs::write(&sink.path, format!("{line}\n"))
            .with_context(|| format!("writing {}", sink.path.display()))?;
    }
    if let Some(p) = &trace_path {
        write_trace_file(p)?;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.reject_unknown(&["in", "out", "steps", "cache-steps",
                          "lag-policy", "operators", "trace",
                          "metrics", "metrics-interval"])?;
    let input = args.get("in").context("--in required")?;
    let output = args
        .get("out")
        .context("--out required (serve+ADDR listen endpoint)")?;
    let cache_steps: usize = args.get_parse_or("cache-steps", 4)?;
    let lag = LagPolicy::parse(args.get_or("lag-policy", "drop"))?;
    let (trace_path, metrics_sink) = obs_from_args(args)?;
    let sink = SinkSpec::parse(output)?;
    let SinkSpec::Serve { listen } = &sink else {
        bail!(
            "serve needs a serve+ADDR --out endpoint to listen on, \
             got {sink}"
        );
    };
    let source = SourceSpec::parse(input)?;
    let mut upstream = source
        .open(ReaderSlot::solo())
        .with_context(|| format!("opening serve input {source}"))?;
    let opts = CommonOptions::from_args(args)?
        .metrics(metrics_sink)
        .serve(
            listen.clone(),
            sink.transport().to_string(),
            cache_steps,
            lag,
        );
    obs::trace::set_thread_identity(opts.rank, "serve");
    let mut daemon = ServeDaemon::start(opts)?;
    println!(
        "serving {source} on {} (cache {cache_steps} steps, lag {lag})",
        daemon.address()
    );
    let report = daemon.pump(upstream.as_mut())?;
    upstream.close()?;
    println!("{}", report.summary());
    if !report.ops.is_empty() {
        println!("{}", ops_summary(&report.ops));
    }
    for s in &report.subscribers {
        println!(
            "  subscriber {}: {} steps announced, {} dropped, {} out",
            s.rank,
            s.announced_steps,
            s.dropped_steps,
            fmt_bytes(s.egress_bytes)
        );
    }
    if let Some(p) = &trace_path {
        write_trace_file(p)?;
    }
    Ok(())
}

/// Append one metrics line (create the file on first use).
fn append_metrics_line(path: &std::path::Path, line: &str) -> Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    writeln!(f, "{line}")?;
    Ok(())
}

fn cmd_produce(args: &Args) -> Result<()> {
    args.reject_unknown(&["out", "engine", "steps", "particles",
                          "no-runtime", "period", "operators",
                          "trace", "metrics", "metrics-interval"])?;
    let out = args.get("out").context("--out required")?;
    let steps: u64 = args.get_parse_or("steps", 10)?;
    let period: u64 = args.get_parse_or("period", 10)?;
    let n: usize = args.get_parse_or("particles", 100_000)?;
    let runtime = if args.flag("no-runtime") {
        None
    } else {
        Some(Runtime::load_default().context(
            "loading artifacts (use --no-runtime for the rust fallback)",
        )?)
    };
    let mut producer = KhProducer::new(
        0, "localhost", n, 0, n as u64, 42, runtime.as_ref())?;
    if let Some(chain) = parse_operators(args)? {
        producer.set_operators(chain);
    }
    let sink = sink_from_args(args, out)?;
    let mut engine: Box<dyn Engine> = sink
        .open_writer(ReaderSlot::solo())
        .with_context(|| format!("opening produce output {sink}"))?;
    let (trace_path, metrics_sink) = obs_from_args(args)?;
    obs::trace::set_thread_identity(0, "produce");
    let metrics_base = metrics_sink.as_ref().map(|s| {
        let _ = std::fs::write(&s.path, "");
        obs::metrics::snapshot_metrics()
    });
    let mut series = Series::new("openpmd-stream", "openpmd-stream produce");
    let t0 = std::time::Instant::now();
    for out_step in 0..steps {
        let _sp = obs::trace::span("produce.step").with("step", out_step);
        for _ in 0..period {
            producer.step()?;
        }
        let status =
            producer.write_iteration(&mut series, engine.as_mut(), out_step)?;
        println!(
            "iteration {out_step}: sim step {} t={:.2}s status {status:?}",
            producer.steps_taken(),
            t0.elapsed().as_secs_f64()
        );
        if let (Some(sink), Some(base)) = (&metrics_sink, &metrics_base) {
            if (out_step + 1) % sink.every == 0 {
                let snap = obs::metrics::snapshot_metrics().delta(base);
                append_metrics_line(
                    &sink.path,
                    &obs::export::metrics_line(Some(out_step), &snap),
                )?;
            }
        }
    }
    let ops_report = engine.ops_report();
    engine.close()?;
    if let (Some(sink), Some(base)) = (&metrics_sink, &metrics_base) {
        let snap = obs::metrics::snapshot_metrics().delta(base);
        append_metrics_line(
            &sink.path,
            &obs::export::metrics_line(None, &snap),
        )?;
    }
    if let Some(p) = &trace_path {
        write_trace_file(p)?;
    }
    println!(
        "produced {steps} iterations of {n} particles ({} each)",
        fmt_bytes(n as u64 * 7 * 4)
    );
    if !ops_report.is_empty() {
        println!("{}", ops_summary(&ops_report));
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    args.reject_unknown(&["in", "q-max", "csv", "no-runtime", "steps"])?;
    let input = args.get("in").context("--in required")?;
    let q_max: f32 = args.get_parse_or("q-max", 2.0)?;
    let csv = args.get_or("csv", "scatter.csv");
    let runtime = if args.flag("no-runtime") {
        None
    } else {
        Some(Runtime::load_default()?)
    };
    let source = SourceSpec::parse(input)?;
    let mut reader = source
        .open(ReaderSlot::solo())
        .with_context(|| format!("opening analyze input {source}"))?;
    let mut analyzer = SaxsAnalyzer::new(q_max, runtime.as_ref())?;
    let max_steps = args.get_parse::<u64>("steps")?.unwrap_or(u64::MAX);
    let mut steps = 0;
    while steps < max_steps {
        match reader.begin_step()? {
            StepStatus::Ok => {}
            _ => break,
        }
        // Find the particle position/weighting variables of this step.
        let vars = reader.available_variables();
        let find = |suffix: &str| {
            vars.iter().find(|v| v.name.ends_with(suffix)).cloned()
        };
        let (Some(px), Some(py), Some(pz), Some(w)) = (
            find("/position/x"),
            find("/position/y"),
            find("/position/z"),
            find("/weighting"),
        ) else {
            bail!("step lacks e/position + weighting records");
        };
        let n = px.shape[0];
        let sel = Chunk::whole(vec![n]);
        // Two-phase: defer all four component loads, perform them as one
        // batch (one seek-ordered sweep over the BP step), then redeem.
        let hx = reader.get_deferred(&px.name, sel.clone())?;
        let hy = reader.get_deferred(&py.name, sel.clone())?;
        let hz = reader.get_deferred(&pz.name, sel.clone())?;
        let hw = reader.get_deferred(&w.name, sel)?;
        reader.perform_gets()?;
        let x = cast::bytes_to_f32(&reader.take_get(hx)?)?;
        let y = cast::bytes_to_f32(&reader.take_get(hy)?)?;
        let z = cast::bytes_to_f32(&reader.take_get(hz)?)?;
        let wv = cast::bytes_to_f32(&reader.take_get(hw)?)?;
        let mut pos = Vec::with_capacity(x.len() * 3);
        for i in 0..x.len() {
            pos.extend_from_slice(&[x[i], y[i], z[i]]);
        }
        analyzer.consume(&pos, &wv)?;
        reader.end_step()?;
        steps += 1;
    }
    analyzer.write_csv(csv)?;
    println!(
        "analyzed {steps} steps, {} macroparticles -> {csv}",
        analyzer.atoms_seen
    );
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    args.reject_unknown(&["in"])?;
    let input = args.get("in").context("--in required")?;
    let source = SourceSpec::parse(input)?;
    let mut reader = source
        .open(ReaderSlot::solo())
        .with_context(|| format!("opening validate input {source}"))?;
    let mut all_ok = true;
    let mut steps = 0;
    loop {
        let (status, parsed) = Series::read_iteration(reader.as_mut())?;
        if status != StepStatus::Ok {
            break;
        }
        let (index, iteration) = parsed.unwrap();
        let findings = validate::validate_iteration(index, &iteration);
        for f in &findings {
            println!("{:?} {}: {}", f.severity, f.path, f.message);
        }
        all_ok &= validate::is_conformant(&findings);
        reader.end_step()?;
        steps += 1;
    }
    println!(
        "{steps} iterations checked: {}",
        if all_ok { "conformant" } else { "NON-CONFORMANT" }
    );
    if !all_ok {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.reject_unknown(&["in"])?;
    let input = args.get("in").context("--in required")?;
    let source = SourceSpec::parse(input)?;
    let mut reader = source
        .open(ReaderSlot::solo())
        .with_context(|| format!("opening info input {source}"))?;
    let mut step = 0;
    while reader.begin_step()? == StepStatus::Ok {
        println!("step {step}:");
        for name in reader.attribute_names() {
            if let Some(v) = reader.attribute(&name) {
                println!("  attr {name} = {v}");
            }
        }
        for v in reader.available_variables() {
            let chunks = reader.available_chunks(&v.name);
            println!(
                "  var {} {} shape {:?} ({} chunks)",
                v.name,
                v.dtype.name(),
                v.shape,
                chunks.len()
            );
            for c in chunks.iter().take(4) {
                println!(
                    "      chunk @{:?}+{:?} rank {} host {}",
                    c.chunk.offset, c.chunk.extent, c.source_rank,
                    c.hostname
                );
            }
            if chunks.len() > 4 {
                println!("      ... {} more", chunks.len() - 4);
            }
        }
        reader.end_step()?;
        step += 1;
    }
    Ok(())
}

fn cmd_systems() -> Result<()> {
    let mut t = Table::new(
        "Table 1: system performance, OLCF Titan to Frontier",
        &["system", "compute [PFlop/s]", "PFS bw [TiB/s]",
          "capacity [PiB]", "50-dump storage need [PiB]"],
    );
    for s in systems::table1_systems() {
        let (blo, bhi) = s.pfs_bandwidth;
        let (clo, chi) = s.pfs_capacity;
        let tib = |x: f64| x / (1u64 << 40) as f64;
        let pib = |x: f64| x / (1u64 << 50) as f64;
        t.row(vec![
            s.name.into(),
            format!("{}", s.compute_pflops),
            if blo == bhi {
                format!("{:.1}", tib(blo))
            } else {
                format!("{:.0}-{:.0}", tib(blo), tib(bhi))
            },
            if clo == chi {
                format!("{:.0}", pib(clo))
            } else {
                format!("{:.0}-{:.0}", pib(clo), pib(chi))
            },
            format!("{:.1}", s.storage_requirement(50) as f64
                    / (1u64 << 50) as f64),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
