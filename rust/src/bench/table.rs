//! ASCII tables + CSV emission for bench outputs, the shared `--smoke`
//! flag, and the machine-readable `BENCH_*.json` emitter consumed by
//! the CI perf-regression gate (`tools/bench_compare.rs`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::cli::Args;
use crate::util::json::Json;

/// A simple left-aligned table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(),
                   "row width != header width");
        self.rows.push(cells);
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.columns));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    /// CSV form (for replotting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.columns.iter().map(|c| esc(c)).collect::<Vec<_>>()
                .join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write the CSV next to the bench outputs (under `bench-results/`).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::PathBuf::from("bench-results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

// ---------------------------------------------------------------------
// Shared bench plumbing
// ---------------------------------------------------------------------

/// The one `--smoke` convention every `benches/*.rs` main follows: the
/// flag (or the bench's env key, e.g. `FIG8_SMOKE=1`) shrinks sizes to
/// CI scale and announces it. Centralized so no bench grows its own
/// variant spelling.
pub fn smoke_mode(args: &Args, env_key: &str) -> bool {
    let smoke = args.flag("smoke") || std::env::var(env_key).is_ok();
    if smoke {
        println!("[smoke mode: tiny sizes]");
    }
    smoke
}

/// One metric inside a [`BenchJson`] document.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BenchMetric {
    pub value: f64,
    /// Direction of goodness for the regression gate.
    pub higher_is_better: bool,
    /// Whether `bench-compare` fails the job on a regression of this
    /// metric. Structural quantities (ratios, imbalance factors) gate;
    /// absolute wall-clock throughput on shared CI runners is recorded
    /// (`false`) so the trajectory stays inspectable without flaking.
    pub gate: bool,
}

/// Machine-readable bench result: written as
/// `bench-results/BENCH_<name>.json`, diffed against the committed
/// `bench/baseline/BENCH_<name>.json` by the `bench-compare` CI step,
/// and uploaded as a workflow artifact so every PR's perf trajectory
/// is inspectable.
#[derive(Clone, Debug, Default)]
pub struct BenchJson {
    pub name: String,
    pub metrics: BTreeMap<String, BenchMetric>,
}

impl BenchJson {
    pub fn new(name: &str) -> BenchJson {
        BenchJson { name: name.to_string(), metrics: BTreeMap::new() }
    }

    /// Record a gated metric (the regression gate compares it).
    pub fn gauge(&mut self, key: &str, value: f64,
                 higher_is_better: bool) {
        self.metrics.insert(
            key.to_string(),
            BenchMetric { value, higher_is_better, gate: true },
        );
    }

    /// Record an ungated metric (kept for the artifact trail only).
    pub fn info(&mut self, key: &str, value: f64) {
        self.metrics.insert(
            key.to_string(),
            BenchMetric { value, higher_is_better: true, gate: false },
        );
    }

    pub fn to_json(&self) -> Json {
        let mut metrics = BTreeMap::new();
        for (key, m) in &self.metrics {
            let mut obj = BTreeMap::new();
            obj.insert("value".to_string(), Json::Num(m.value));
            obj.insert("higherIsBetter".to_string(),
                       Json::Bool(m.higher_is_better));
            obj.insert("gate".to_string(), Json::Bool(m.gate));
            metrics.insert(key.clone(), Json::Obj(obj));
        }
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str(self.name.clone()));
        doc.insert("metrics".to_string(), Json::Obj(metrics));
        Json::Obj(doc)
    }

    /// Parse a document produced by [`BenchJson::to_json`] (the
    /// `bench-compare` tool's input path).
    pub fn from_json(doc: &Json) -> Result<BenchJson, String> {
        let name = doc
            .get("bench")
            .and_then(|b| b.as_str())
            .ok_or("missing \"bench\" name")?
            .to_string();
        let mut metrics = BTreeMap::new();
        let obj = doc
            .get("metrics")
            .and_then(|m| m.as_obj())
            .ok_or("missing \"metrics\" object")?;
        for (key, m) in obj {
            let value = m
                .get("value")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("metric {key:?} lacks a value"))?;
            let flag = |name: &str| -> bool {
                matches!(m.get(name), Some(Json::Bool(true)))
            };
            metrics.insert(
                key.clone(),
                BenchMetric {
                    value,
                    higher_is_better: flag("higherIsBetter"),
                    gate: flag("gate"),
                },
            );
        }
        Ok(BenchJson { name, metrics })
    }

    /// Write `bench-results/BENCH_<name>.json` (same directory as the
    /// CSV outputs) and return the path.
    pub fn save(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::PathBuf::from("bench-results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string_pretty())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_round_trips() {
        let mut b = BenchJson::new("fleet");
        b.gauge("imbalance", 1.25, false);
        b.info("aggregate_mibps", 812.5);
        let doc = b.to_json();
        let parsed = crate::util::json::parse(&doc.to_string()).unwrap();
        let back = BenchJson::from_json(&parsed).unwrap();
        assert_eq!(back.name, "fleet");
        assert_eq!(back.metrics.len(), 2);
        let im = back.metrics["imbalance"];
        assert_eq!(im, BenchMetric {
            value: 1.25,
            higher_is_better: false,
            gate: true,
        });
        assert!(!back.metrics["aggregate_mibps"].gate);
    }

    #[test]
    fn bench_json_rejects_malformed_docs() {
        for bad in [
            "{}",
            r#"{"bench": "x"}"#,
            r#"{"bench": "x", "metrics": {"m": {}}}"#,
        ] {
            let doc = crate::util::json::parse(bad).unwrap();
            assert!(BenchJson::from_json(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "23456".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("alpha"));
        let lines: Vec<&str> = s.lines().collect();
        // Header, separator and rows all share the same width.
        assert_eq!(lines[1].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
