//! ASCII tables + CSV emission for bench outputs.

use std::fmt::Write as _;

/// A simple left-aligned table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(),
                   "row width != header width");
        self.rows.push(cells);
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.columns));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    /// CSV form (for replotting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.columns.iter().map(|c| esc(c)).collect::<Vec<_>>()
                .join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write the CSV next to the bench outputs (under `bench-results/`).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::PathBuf::from("bench-results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "23456".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("alpha"));
        let lines: Vec<&str> = s.lines().collect();
        // Header, separator and rows all share the same width.
        assert_eq!(lines[1].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
