//! §4.2/4.3 simulation: the PIConGPU → GAPD staged pipeline (Figs. 8 &
//! 9, GPU-share experiment).
//!
//! Workload: 3 producer + 3 analysis ranks per node; each producer
//! contributes one ~3.1 GiB particle chunk per exchange (sizes jittered
//! ±5% — particle counts drift in a real KH run, and this jitter is what
//! de-aligns the Next-Fit bins from node boundaries, exactly the
//! misalignment the paper's strategy (2) suffers).
//!
//! The *real* §3 strategies plan the simulated flows: the chunk table is
//! handed to [`crate::distribution`], and the resulting assignment is
//! executed on the DES fabric. Reader-side semantics mirror the
//! openPMD-api of the paper's era: a reader fetches its assigned slices
//! *sequentially* (one `loadChunk`+flush at a time), so a reader with
//! many partners pays serially — "the number of communication partners
//! [...] suggesting that controlling this number is important" (§4.3).
//!
//! TCP additionally models incast/convergence collapse for multi-partner
//! readers (synchronized many-to-one bursts collapse socket goodput; the
//! effect RDMA's credit-based flow control avoids).

use crate::cluster::des::{Event, Sim};
use crate::cluster::network::{
    workload, FabricModel, StragglerModel, TransportKind,
};
use crate::cluster::topology::{ClusterLayout, Placement};
use crate::distribution::{self, ChunkTable, Strategy};
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::pipeline::metrics::{OpKind, PerceivedThroughput};
use crate::util::rng::Rng;

/// Parameters of one Fig. 8 configuration.
#[derive(Clone)]
pub struct Fig8Params {
    pub nodes: usize,
    pub writers_per_node: usize,
    pub readers_per_node: usize,
    pub bytes_per_writer: u64,
    /// Relative chunk-size jitter (fraction).
    pub size_jitter: f64,
    pub transport: TransportKind,
    /// Strategy name for [`distribution::by_name`].
    pub strategy: String,
    /// Exchanges to simulate per run.
    pub steps: usize,
    pub fabric: FabricModel,
    pub seed: u64,
}

impl Default for Fig8Params {
    fn default() -> Self {
        Fig8Params {
            nodes: 64,
            writers_per_node: 3,
            readers_per_node: 3,
            bytes_per_writer: workload::BYTES_PER_PRODUCER_PARTICLES,
            size_jitter: 0.012,
            transport: TransportKind::Rdma,
            strategy: "hyperslabs".into(),
            steps: 5,
            fabric: FabricModel::summit(),
            seed: 1,
        }
    }
}

/// Per-step SST synchronization overhead (begin-step rendezvous,
/// metadata aggregation), seconds. Calibrated against the paper's
/// ~0.9 s median RDMA load times (Fig. 9).
fn step_overhead(t: TransportKind) -> f64 {
    match t {
        TransportKind::Rdma => 0.45,
        TransportKind::Tcp => 1.2,
    }
}

/// TCP incast collapse: effective per-connection bandwidth divisor for
/// a reader assembling from several sources (synchronized many-to-one
/// bursts collapse socket goodput; RDMA's credit-based flow control
/// avoids this).
fn tcp_incast_divisor(partners: usize) -> f64 {
    if partners <= 1 {
        1.0
    } else {
        5.0 * (partners - 1) as f64
    }
}

/// Result of one configuration run.
pub struct Fig8Run {
    /// Writer-side perceived sends (Fig. 8 plots this aggregate).
    pub store_metrics: PerceivedThroughput,
    /// Reader-side perceived loads (Fig. 9 boxplots).
    pub load_metrics: PerceivedThroughput,
    /// Count of readers that received >= 1.9x the ideal volume
    /// (the binpacking worst case observed in Fig. 9).
    pub worst_case_events: usize,
    pub writers: usize,
    pub readers: usize,
}

/// Build the (jittered) chunk table for one exchange.
///
/// Chunk *offsets* follow writer-rank order (how PIConGPU lays out its
/// particle index space), but the metadata arrives in arbitrary order —
/// ADIOS keeps chunk tables in hash-map order — so the list is shuffled.
/// Geometric strategies (hyperslabs, by-hostname) are order-insensitive;
/// order-sensitive ones (round-robin, binpacking) see the arrival order,
/// which is what disperses binpacking's bins across the machine (§4.3).
fn chunk_table(p: &Fig8Params, placement: &Placement, rng: &mut Rng)
    -> ChunkTable
{
    let mut chunks = Vec::with_capacity(placement.writers.len());
    let mut off = 0u64;
    for w in &placement.writers {
        let jitter = 1.0 + p.size_jitter * (2.0 * rng.f64() - 1.0);
        let size = (p.bytes_per_writer as f64 * jitter) as u64;
        chunks.push(WrittenChunkInfo::new(
            Chunk::new(vec![off], vec![size]),
            w.rank,
            w.hostname.clone(),
        ));
        off += size;
    }
    rng.shuffle(&mut chunks);
    ChunkTable { dataset_extent: vec![off], chunks }
}

/// Simulate one configuration.
pub fn simulate(p: &Fig8Params) -> Fig8Run {
    let cluster = ClusterLayout::summit(p.nodes);
    let placement =
        Placement::co_scheduled(cluster, p.writers_per_node,
                                p.readers_per_node);
    let readers = placement.reader_layout();
    let strategy: Box<dyn Strategy> =
        distribution::by_name(&p.strategy).expect("strategy name");
    let tmodel = p.transport.model();
    let stragglers = StragglerModel::streaming();
    let mut rng = Rng::new(p.seed);

    let node_of_writer: Vec<usize> =
        placement.writers.iter().map(|w| w.node).collect();
    let node_of_reader: Vec<usize> =
        placement.readers.iter().map(|r| r.node).collect();

    let mut run = Fig8Run {
        store_metrics: PerceivedThroughput::new(),
        load_metrics: PerceivedThroughput::new(),
        worst_case_events: 0,
        writers: placement.writers.len(),
        readers: placement.readers.len(),
    };

    for step in 0..p.steps {
        let table = chunk_table(p, &placement, &mut rng);
        let assignment = strategy.distribute(&table, &readers);
        let ideal = table.total_elements() as f64
            / readers.len().max(1) as f64;

        let mut sim = Sim::new();
        let nic_out: Vec<_> = (0..p.nodes)
            .map(|_| sim.add_resource(p.fabric.nic_bandwidth))
            .collect();
        let nic_in: Vec<_> = (0..p.nodes)
            .map(|_| sim.add_resource(p.fabric.nic_bandwidth))
            .collect();

        // Per-reader sequential slice queues (see module docs).
        struct ReaderState {
            queue: std::collections::VecDeque<(usize, f64)>, // (writer, bytes)
            bytes: u64,
            requests: usize,
            done_at: f64,
            cap: f64,
            remote_partners: usize,
        }
        let mut states: Vec<ReaderState> = Vec::new();
        let mut flow_owner: Vec<usize> = Vec::new(); // flow tag -> reader idx
        for (ri, r) in readers.ranks.iter().enumerate() {
            let slices = assignment.slices(r.rank);
            let partners: std::collections::BTreeSet<usize> =
                slices.iter().map(|s| s.source_rank).collect();
            // Remote partners that supply a *substantial* share of this
            // reader's data need a dedicated staging channel (rendezvous
            // cost); boundary slivers piggyback on the metadata plane.
            let total_bytes: u64 =
                slices.iter().map(|s| s.chunk.num_elements()).sum();
            let mut per_partner: std::collections::BTreeMap<usize, (bool, u64)> =
                std::collections::BTreeMap::new();
            for s in slices {
                let e = per_partner
                    .entry(s.source_rank)
                    .or_insert((s.source_host != r.hostname, 0));
                e.1 += s.chunk.num_elements();
            }
            let remote_partners = per_partner
                .values()
                .filter(|(remote, bytes)| {
                    *remote && *bytes * 5 >= total_bytes.max(1)
                })
                .count();
            let cap = match p.transport {
                TransportKind::Rdma => tmodel.per_conn_bandwidth,
                TransportKind::Tcp => {
                    tmodel.per_conn_bandwidth
                        / tcp_incast_divisor(partners.len())
                }
            };
            let mut queue = std::collections::VecDeque::new();
            let mut bytes = 0u64;
            for s in slices {
                let sz = s.chunk.num_elements();
                bytes += sz;
                let slow = stragglers.draw(p.nodes, &mut rng);
                queue.push_back((s.source_rank, sz as f64 * slow));
            }
            if bytes as f64 >= 1.9 * ideal && ideal > 0.0 {
                run.worst_case_events += 1;
            }
            states.push(ReaderState {
                queue,
                bytes,
                requests: 0,
                done_at: 0.0,
                cap,
                remote_partners,
            });
            let _ = ri;
        }

        // Writer completion tracking (perceived store = time until the
        // last byte this writer owns has been pulled).
        let mut writer_done = vec![0.0f64; placement.writers.len()];
        let mut writer_bytes = vec![0u64; placement.writers.len()];

        // Kick off the first slice of every reader.
        let start_next = |sim: &mut Sim,
                              states: &mut Vec<ReaderState>,
                              flow_owner: &mut Vec<usize>,
                              ri: usize| {
            if let Some((writer_rank, bytes)) = states[ri].queue.pop_front()
            {
                let wnode = node_of_writer[writer_rank];
                let rnode = node_of_reader[ri];
                let tag = flow_owner.len() as u64;
                flow_owner.push(ri);
                states[ri].requests += 1;
                let id = sim.add_flow(
                    bytes,
                    vec![nic_out[wnode], nic_in[rnode]],
                    states[ri].cap,
                    tag,
                );
                Some((id, writer_rank, bytes as u64))
            } else {
                None
            }
        };
        let mut flow_writer: std::collections::HashMap<u64, usize> =
            std::collections::HashMap::new();
        for ri in 0..states.len() {
            if let Some((id, w, b)) =
                start_next(&mut sim, &mut states, &mut flow_owner, ri)
            {
                flow_writer.insert(sim.flow_tag(id), w);
                writer_bytes[w] += b;
            }
        }
        while let Some(ev) = sim.next_event() {
            if let Event::FlowDone { id, at } = ev {
                let tag = sim.flow_tag(id);
                let ri = flow_owner[tag as usize];
                let w = flow_writer[&tag];
                // The writer's step is released when its reader finishes,
                // including the reader's per-partner rendezvous costs.
                let reader_extra = tmodel.remote_rendezvous
                    * states[ri].remote_partners as f64;
                writer_done[w] = writer_done[w].max(at + reader_extra);
                states[ri].done_at = at;
                if let Some((id2, w2, b2)) =
                    start_next(&mut sim, &mut states, &mut flow_owner, ri)
                {
                    flow_writer.insert(sim.flow_tag(id2), w2);
                    writer_bytes[w2] += b2;
                }
            }
        }

        // Record samples.
        for (ri, st) in states.iter().enumerate() {
            if st.bytes == 0 {
                continue;
            }
            let secs = st.done_at
                + step_overhead(p.transport)
                + tmodel.per_message_overhead * st.requests as f64
                + tmodel.remote_rendezvous * st.remote_partners as f64;
            run.load_metrics.record_sim(
                OpKind::Load, st.bytes, secs, step as u64, ri);
        }
        for (w, &done) in writer_done.iter().enumerate() {
            if writer_bytes[w] == 0 {
                continue;
            }
            let secs = done + step_overhead(p.transport);
            run.store_metrics.record_sim(
                OpKind::Store,
                table.chunks[w].chunk.num_elements(),
                secs,
                step as u64,
                w,
            );
        }
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GIB, TIB};

    fn run(nodes: usize, strategy: &str, transport: TransportKind)
        -> Fig8Run
    {
        simulate(&Fig8Params {
            nodes,
            strategy: strategy.into(),
            transport,
            steps: 3,
            seed: 7,
            ..Default::default()
        })
    }

    #[test]
    fn rdma_hyperslabs_median_load_matches_paper() {
        // Fig. 9: ~0.9 s medians.
        let r = run(64, "hyperslabs", TransportKind::Rdma);
        let med = r.load_metrics.report(OpKind::Load, r.readers).times.median;
        assert!((0.5..1.6).contains(&med), "median load {med}");
    }

    #[test]
    fn rdma_binpacking_is_consistently_worse() {
        // Fig. 8: strategy (2) well below (1) and (3) at every scale.
        for nodes in [16, 64] {
            let hs = run(nodes, "hyperslabs", TransportKind::Rdma);
            let bp = run(nodes, "binpacking", TransportKind::Rdma);
            let hs_rate = hs
                .store_metrics
                .report(OpKind::Store, hs.writers)
                .aggregate_rate;
            let bp_rate = bp
                .store_metrics
                .report(OpKind::Store, bp.writers)
                .aggregate_rate;
            assert!(
                bp_rate < 0.62 * hs_rate,
                "nodes={nodes}: binpacking {bp_rate} vs hyperslabs {hs_rate}"
            );
        }
    }

    #[test]
    fn hostname_and_hyperslabs_overlap() {
        // Fig. 8: "the by hostname and hyperslabs strategy results
        // overlap each other".
        let hs = run(64, "hyperslabs", TransportKind::Rdma);
        let bh = run(64, "hostname", TransportKind::Rdma);
        let a = hs.store_metrics.report(OpKind::Store, hs.writers)
            .aggregate_rate;
        let b = bh.store_metrics.report(OpKind::Store, bh.writers)
            .aggregate_rate;
        let ratio = a / b;
        assert!((0.7..1.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn sockets_lose_badly() {
        let rdma = run(64, "hyperslabs", TransportKind::Rdma);
        let tcp = run(64, "hyperslabs", TransportKind::Tcp);
        let a = rdma.store_metrics.report(OpKind::Store, rdma.writers)
            .aggregate_rate;
        let b = tcp.store_metrics.report(OpKind::Store, tcp.writers)
            .aggregate_rate;
        assert!(b < 0.45 * a, "tcp {b} vs rdma {a}");
    }

    #[test]
    fn sockets_plus_binpacking_collapse() {
        // Paper: loading times "up to and above three minutes".
        let r = run(64, "binpacking", TransportKind::Tcp);
        let rep = r.load_metrics.report(OpKind::Load, r.readers);
        assert!(rep.times.max > 15.0,
                "worst tcp binpack load only {}s", rep.times.max);
    }

    #[test]
    fn rdma_512_nodes_absolute_throughput_in_range() {
        let r = simulate(&Fig8Params {
            nodes: 512,
            strategy: "hyperslabs".into(),
            steps: 2,
            seed: 3,
            ..Default::default()
        });
        let rate = r.store_metrics.report(OpKind::Store, r.writers)
            .aggregate_rate;
        // Paper: 5.12 TiB/s. Accept a generous band for the model.
        assert!(rate > 2.0 * TIB as f64 && rate < 9.0 * TIB as f64,
                "{}", crate::util::bytes::fmt_rate(rate));
    }

    #[test]
    fn binpacking_worst_case_occurs_sometimes() {
        // Fig. 9's outlier: a reader receiving ~2x ideal exists across
        // enough seeds.
        let mut events = 0;
        for seed in 0..12 {
            let r = simulate(&Fig8Params {
                nodes: 32,
                strategy: "binpacking".into(),
                steps: 4,
                seed,
                ..Default::default()
            });
            events += r.worst_case_events;
        }
        assert!(events > 0, "2x-ideal worst case never materialized");
    }

    #[test]
    fn bytes_accounted_completely() {
        let r = run(16, "hostname", TransportKind::Rdma);
        let loads = r.load_metrics.report(OpKind::Load, r.readers);
        // 3 steps x 48 writers x ~3.1 GiB (jittered +-5%).
        let want = 3.0 * 48.0 * 3.1 * GIB as f64;
        let got = loads.total_bytes as f64;
        assert!((got - want).abs() / want < 0.06, "got {got}, want {want}");
    }
}
