//! Measured micro-benchmark loop (criterion substitute).
//!
//! Warms up, then runs timed samples until both a minimum sample count
//! and a minimum measuring time are reached; reports mean/median/p95 and
//! ops/s. Deliberately simple: no outlier rejection beyond the median,
//! no statistical tests — the numbers feed EXPERIMENTS.md §Perf tables,
//! not regressions dashboards.

use std::time::{Duration, Instant};

use crate::util::stats::{boxplot, BoxPlot};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    /// Per-iteration seconds.
    pub stats: BoxPlot,
    pub mean: f64,
}

impl BenchResult {
    pub fn per_iter(&self) -> Duration {
        Duration::from_secs_f64(self.mean)
    }

    /// Iterations per second.
    pub fn rate(&self) -> f64 {
        if self.mean > 0.0 { 1.0 / self.mean } else { f64::INFINITY }
    }

    /// Render one line: `name  median  mean  p-ish  rate`.
    pub fn render(&self) -> String {
        format!(
            "{:<44} {:>12}/iter (median {:>12}, n={})",
            self.name,
            crate::util::fmt_duration(Duration::from_secs_f64(self.mean)),
            crate::util::fmt_duration(Duration::from_secs_f64(
                self.stats.median
            )),
            self.samples
        )
    }

    /// Throughput line for byte-moving benches.
    pub fn render_bytes(&self, bytes_per_iter: u64) -> String {
        let rate = bytes_per_iter as f64 / self.mean;
        format!(
            "{:<44} {:>14} ({:>12}/iter, n={})",
            self.name,
            crate::util::bytes::fmt_rate(rate),
            crate::util::fmt_duration(Duration::from_secs_f64(self.mean)),
            self.samples
        )
    }
}

/// Run `f` repeatedly: `warmup` untimed iterations, then at least
/// `min_samples` timed ones and at least `min_time` of total measurement.
pub fn bench_loop(
    name: &str,
    warmup: usize,
    min_samples: usize,
    min_time: Duration,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(min_samples * 2);
    let started = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= min_samples && started.elapsed() >= min_time {
            break;
        }
        if samples.len() >= 1_000_000 {
            break; // guard against being handed a no-op
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        samples: samples.len(),
        stats: boxplot(&samples),
        mean,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let r = bench_loop(
            "sleep-2ms",
            1,
            5,
            Duration::from_millis(1),
            || std::thread::sleep(Duration::from_millis(2)),
        );
        assert!(r.mean >= 0.002, "{}", r.mean);
        assert!(r.mean < 0.05, "{}", r.mean);
        assert!(r.samples >= 5);
        assert!(r.rate() < 500.0);
    }

    #[test]
    fn render_contains_name() {
        let r = bench_loop("nm", 0, 3, Duration::ZERO, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.render().contains("nm"));
        assert!(r.render_bytes(1024).contains("/s"));
    }
}
