//! Benchmark harness (S18): simulated paper experiments + formatting +
//! a criterion-substitute timing loop (criterion is unavailable
//! offline; `benches/*.rs` are plain `harness = false` mains built on
//! this module).
//!
//! * [`fig6`] — §4.1 asynchronous-IO pipeline simulation (BP-only vs
//!   SST+BP), regenerating Fig. 6, Fig. 7 and the dump-count / IO-share
//!   numbers quoted in the text.
//! * [`fig8`] — §4.2/4.3 simulation–analysis pipeline simulation
//!   (distribution strategies × transports), regenerating Fig. 8 and
//!   Fig. 9. Uses the *real* distribution strategies to plan the
//!   simulated flows.
//! * [`table`] — ASCII tables and CSV emission for the bench outputs.
//! * [`timing`] — measured (not simulated) micro-bench loop.

pub mod fig6;
pub mod fig8;
pub mod table;
pub mod timing;

pub use table::{smoke_mode, BenchJson, BenchMetric, Table};
pub use timing::{bench_loop, BenchResult};
