//! §4.1 simulation: streaming as asynchronous IO (Figs. 6 & 7, dump
//! counts, IO-time shares).
//!
//! Workload (paper Fig. 5): each node runs 6 PIConGPU instances
//! (9.14 GiB per instance per output step) and one `openpmd-pipe`
//! instance. Two setups:
//!
//! * **BP-only** — the simulation writes node-aggregated BP files
//!   directly and *blocks* during IO; PIConGPU steps in lockstep, so a
//!   dump cycle ends when the slowest node's write finishes (stragglers
//!   couple globally).
//! * **SST+BP** — instances hand their step to the node-local pipe via
//!   SST (producer blocks only for the staging copy); the pipe loads the
//!   stream and writes the aggregated file asynchronously. When a node's
//!   pipe is still busy at the next output period, that step is
//!   *discarded* (`QueueFullPolicy=Discard`, queue depth 1) — "IO
//!   granularity is automatically reduced".
//!
//! Each run simulates 15 minutes; the driver benches repeat 3x with
//! different seeds (the paper's protocol).

use crate::cluster::des::{Event, Sim};
use crate::cluster::network::{workload, FabricModel, StragglerModel};
use crate::pipeline::metrics::{OpKind, PerceivedThroughput};
use crate::util::rng::Rng;

/// Which §4.1 setup to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Setup {
    BpOnly,
    SstBp,
}

/// Parameters of one run.
#[derive(Clone, Debug)]
pub struct Fig6Params {
    pub nodes: usize,
    pub producers_per_node: usize,
    pub bytes_per_producer: u64,
    pub duration_s: f64,
    pub compute_per_period_s: f64,
    pub fabric: FabricModel,
    pub seed: u64,
}

impl Default for Fig6Params {
    fn default() -> Self {
        Fig6Params {
            nodes: 64,
            producers_per_node: 6,
            bytes_per_producer: workload::BYTES_PER_PRODUCER_FULL,
            duration_s: 900.0,
            compute_per_period_s: workload::COMPUTE_PER_OUTPUT_PERIOD,
            fabric: FabricModel::summit(),
            seed: 1,
        }
    }
}

/// Result of one run.
#[derive(Debug)]
pub struct Fig6Run {
    pub setup: Setup,
    pub nodes: usize,
    /// Successfully written dumps (per-node average, rounded).
    pub dumps: u64,
    /// Dump attempts dropped because the pipe lagged (SST+BP only;
    /// per-node average).
    pub discarded: u64,
    /// Producer-side stores: file writes (BP-only) / staging hand-offs
    /// (SST+BP).
    pub store_metrics: PerceivedThroughput,
    /// Pipe-side streaming loads (SST+BP; the "SST" series of Fig. 6).
    pub load_metrics: PerceivedThroughput,
    /// File-phase writes (BP-only: same as stores; SST+BP: pipe's BP
    /// writes — the "SST+BP" series of Fig. 6).
    pub file_metrics: PerceivedThroughput,
    /// §4.1 text: share of producer runtime spent in raw IO / in the
    /// whole IO plugin (incl. host-side preparation).
    pub raw_io_fraction: f64,
    pub plugin_fraction: f64,
}

/// Simulate one configuration.
pub fn simulate(setup: Setup, p: &Fig6Params) -> Fig6Run {
    match setup {
        Setup::BpOnly => simulate_bp_only(p),
        Setup::SstBp => simulate_sst_bp(p),
    }
}

fn empty_run(setup: Setup, nodes: usize) -> Fig6Run {
    Fig6Run {
        setup,
        nodes,
        dumps: 0,
        discarded: 0,
        store_metrics: PerceivedThroughput::new(),
        load_metrics: PerceivedThroughput::new(),
        file_metrics: PerceivedThroughput::new(),
        raw_io_fraction: 0.0,
        plugin_fraction: 0.0,
    }
}

/// BP-only: per output period every node issues one aggregated write
/// (6 x 9.14 GiB) and the lockstep simulation blocks on the slowest.
fn simulate_bp_only(p: &Fig6Params) -> Fig6Run {
    let mut rng = Rng::new(p.seed);
    let stragglers = StragglerModel::pfs();
    let node_bytes =
        (p.producers_per_node as u64 * p.bytes_per_producer) as f64;
    let meta = p.fabric.pfs.metadata_latency_at(p.nodes);

    let mut run = empty_run(Setup::BpOnly, p.nodes);
    let mut t = 0.0f64;
    let mut io_total = 0.0f64;
    let mut step = 0u64;
    loop {
        t += p.compute_per_period_s;
        if t >= p.duration_s {
            break;
        }
        // All nodes write concurrently; the lockstep barrier is the max.
        let mut sim = Sim::new();
        let agg = sim.add_resource(p.fabric.pfs.aggregate_bandwidth);
        for node in 0..p.nodes {
            let inj = sim.add_resource(p.fabric.pfs.per_node_bandwidth);
            let slow = stragglers.draw(p.nodes, &mut rng);
            sim.add_flow(node_bytes * slow, vec![inj, agg],
                         f64::INFINITY, node as u64);
        }
        let mut max_done = 0.0f64;
        while let Some(ev) = sim.next_event() {
            if let Event::FlowDone { id, at } = ev {
                let node = sim.flow_tag(id) as usize;
                let secs = at + meta;
                run.store_metrics.record_sim(
                    OpKind::Store, node_bytes as u64, secs, step, node);
                run.file_metrics.record_sim(
                    OpKind::Store, node_bytes as u64, secs, step, node);
                max_done = max_done.max(secs);
            }
        }
        t += max_done;
        io_total += max_done;
        run.dumps += 1;
        step += 1;
    }
    let total = t.max(1e-9);
    run.raw_io_fraction = io_total / total;
    // §4.1: the plugin adds host-side data preparation/reorganization —
    // ~10 percentage points over raw IO for the BP path.
    run.plugin_fraction = run.raw_io_fraction + 0.10;
    run
}

/// SST+BP: producers hand off to the node pipe (blocking only for the
/// staging copy); the pipe loads the stream, then writes the aggregated
/// file — all overlapped with the next compute period.
fn simulate_sst_bp(p: &Fig6Params) -> Fig6Run {
    let mut rng = Rng::new(p.seed ^ 0x55);
    let stream_stragglers = StragglerModel::streaming();
    let pfs_stragglers = StragglerModel::pfs();
    let per_prod = p.bytes_per_producer as f64;
    let node_bytes = p.producers_per_node as f64 * per_prod;
    let meta = p.fabric.pfs.metadata_latency_at(p.nodes);
    // Producer-side blocking: copy into the SST staging queue.
    let staging_block = per_prod / p.fabric.staging_copy_bandwidth;

    let mut run = empty_run(Setup::SstBp, p.nodes);
    let mut t = 0.0f64;
    let mut pipe_free_at = vec![0.0f64; p.nodes];
    let mut successes = vec![0u64; p.nodes];
    let mut discards = vec![0u64; p.nodes];
    let mut raw_io_total = 0.0f64;
    let mut step = 0u64;
    loop {
        t += p.compute_per_period_s + staging_block;
        raw_io_total += staging_block;
        if t >= p.duration_s {
            break;
        }
        // Per-node discard decision: pipe still busy -> drop this step.
        let writing: Vec<usize> =
            (0..p.nodes).filter(|&n| pipe_free_at[n] <= t).collect();
        for n in 0..p.nodes {
            if pipe_free_at[n] > t {
                discards[n] += 1;
            }
        }
        if writing.is_empty() {
            step += 1;
            continue;
        }

        // Producer-side store samples (staging hand-off).
        for &node in &writing {
            for prod in 0..p.producers_per_node {
                run.store_metrics.record_sim(
                    OpKind::Store,
                    per_prod as u64,
                    staging_block,
                    step,
                    node * p.producers_per_node + prod,
                );
            }
        }

        // Pipe phase 1: stream loads. Per node, 6 flows share the pipe's
        // ingestion ceiling (and the NIC, which is faster and thus not
        // binding — §4.3's "no IPC advantage" in model form).
        let mut sim = Sim::new();
        for &node in &writing {
            let nic = sim.add_resource(p.fabric.nic_bandwidth);
            let ingest = sim.add_resource(p.fabric.pipe_ingest_bandwidth);
            for prod in 0..p.producers_per_node {
                let slow = stream_stragglers.draw(p.nodes, &mut rng);
                sim.add_flow(
                    per_prod * slow,
                    vec![nic, ingest],
                    f64::INFINITY,
                    (node * p.producers_per_node + prod) as u64,
                );
            }
        }
        let mut stream_done = vec![0.0f64; p.nodes];
        while let Some(ev) = sim.next_event() {
            if let Event::FlowDone { id, at } = ev {
                let inst = sim.flow_tag(id) as usize;
                let node = inst / p.producers_per_node;
                run.load_metrics.record_sim(
                    OpKind::Load, per_prod as u64, at, step, inst);
                stream_done[node] = stream_done[node].max(at);
            }
        }

        // Pipe phase 2: aggregated file write, overlapping compute.
        let mut sim = Sim::new();
        let agg = sim.add_resource(p.fabric.pfs.aggregate_bandwidth);
        for &node in &writing {
            let inj = sim.add_resource(p.fabric.pfs.per_node_bandwidth);
            let slow = pfs_stragglers.draw(p.nodes, &mut rng);
            sim.add_flow(node_bytes * slow, vec![inj, agg],
                         f64::INFINITY, node as u64);
        }
        while let Some(ev) = sim.next_event() {
            if let Event::FlowDone { id, at } = ev {
                let node = sim.flow_tag(id) as usize;
                let secs = at + meta;
                run.file_metrics.record_sim(
                    OpKind::Store, node_bytes as u64, secs, step, node);
                pipe_free_at[node] = t + stream_done[node] + secs;
                successes[node] += 1;
            }
        }
        step += 1;
    }
    let total = t.max(1e-9);
    run.dumps = (successes.iter().sum::<u64>() as f64
        / p.nodes as f64)
        .round() as u64;
    run.discarded = (discards.iter().sum::<u64>() as f64
        / p.nodes as f64)
        .round() as u64;
    run.raw_io_fraction = raw_io_total / total;
    // Communication-latency growth with writer count (paper: 2.1%->6.2%)
    // — a small additive term for step-announce/ack latencies across up
    // to 3072 writers.
    run.raw_io_fraction += 0.012 * (p.nodes as f64 / 64.0).log2().max(0.0);
    // Plugin includes host-side preparation/reorganization: ~25 points
    // (paper: 27%->32%).
    run.plugin_fraction = run.raw_io_fraction + 0.25;
    run
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(nodes: usize, setup: Setup, seed: u64) -> Fig6Run {
        let p = Fig6Params { nodes, seed, ..Default::default() };
        simulate(setup, &p)
    }

    #[test]
    fn bp_only_dump_count_matches_paper_at_64_nodes() {
        // Paper: 22-23 dumps at 64 nodes.
        let r = quick(64, Setup::BpOnly, 3);
        assert!((20..=25).contains(&r.dumps), "dumps={}", r.dumps);
    }

    #[test]
    fn sst_bp_dump_count_matches_paper_at_64_nodes() {
        // Paper: 32-34 dumps at 64 nodes.
        let r = quick(64, Setup::SstBp, 3);
        assert!((31..=36).contains(&r.dumps), "dumps={}", r.dumps);
        assert_eq!(r.discarded, 0, "no discards expected at 64 nodes");
    }

    #[test]
    fn sst_bp_loses_dumps_at_512_nodes() {
        // Paper: only 16-17 dumps at 512 nodes (IO no longer hides).
        let r = quick(512, Setup::SstBp, 3);
        assert!((12..=26).contains(&r.dumps), "dumps={}", r.dumps);
        assert!(r.discarded > 3, "expected discards at 512 nodes, got {}",
                r.discarded);
    }

    #[test]
    fn dump_ordering_matches_paper_shape() {
        // SST+BP > BP-only at 64; the advantage erodes by 512.
        let bp64 = quick(64, Setup::BpOnly, 5).dumps;
        let sst64 = quick(64, Setup::SstBp, 5).dumps;
        let bp512 = quick(512, Setup::BpOnly, 5).dumps;
        let sst512 = quick(512, Setup::SstBp, 5).dumps;
        assert!(sst64 > bp64 + 6, "{sst64} vs {bp64}");
        assert!(sst512 <= bp512 + 4, "{sst512} vs {bp512}");
    }

    #[test]
    fn bp_only_io_fraction_grows_with_scale() {
        let r64 = quick(64, Setup::BpOnly, 1);
        let r512 = quick(512, Setup::BpOnly, 1);
        // Paper: raw 44% -> 55%.
        assert!(r64.raw_io_fraction > 0.30 && r64.raw_io_fraction < 0.55,
                "{}", r64.raw_io_fraction);
        assert!(r512.raw_io_fraction > r64.raw_io_fraction,
                "{} !> {}", r512.raw_io_fraction, r64.raw_io_fraction);
        assert!(r512.plugin_fraction < 0.90);
    }

    #[test]
    fn streaming_raw_io_is_small() {
        let r64 = quick(64, Setup::SstBp, 1);
        let r512 = quick(512, Setup::SstBp, 1);
        // Paper: 2.1% at 64 nodes -> 6.2% at 512.
        assert!(r64.raw_io_fraction < 0.06, "{}", r64.raw_io_fraction);
        assert!(r512.raw_io_fraction > r64.raw_io_fraction);
        assert!(r512.raw_io_fraction < 0.15, "{}", r512.raw_io_fraction);
    }

    #[test]
    fn streaming_throughput_beats_pfs_at_512() {
        use crate::util::bytes::TIB;
        let r = quick(512, Setup::SstBp, 2);
        let stream = r.load_metrics.report(OpKind::Load, 512 * 6);
        // Paper: 4.0-4.3 TiB/s vs the 2.5 TiB/s PFS.
        assert!(stream.aggregate_rate > 2.8 * TIB as f64,
                "{}", crate::util::bytes::fmt_rate(stream.aggregate_rate));
        assert!(stream.aggregate_rate < 6.5 * TIB as f64,
                "{}", crate::util::bytes::fmt_rate(stream.aggregate_rate));
    }

    #[test]
    fn bp_only_capped_by_pfs() {
        use crate::util::bytes::TIB;
        let r = quick(512, Setup::BpOnly, 2);
        let st = r.store_metrics.report(OpKind::Store, 512);
        assert!(st.aggregate_rate < 2.6 * TIB as f64,
                "{}", crate::util::bytes::fmt_rate(st.aggregate_rate));
        assert!(st.aggregate_rate > 0.8 * TIB as f64,
                "{}", crate::util::bytes::fmt_rate(st.aggregate_rate));
    }

    #[test]
    fn stream_load_times_match_fig7() {
        let r = quick(512, Setup::SstBp, 4);
        let times = r.load_metrics.report(OpKind::Load, 512 * 6).times;
        // Paper Fig. 7: medians 5-7 s, worst outlier just above 9 s.
        assert!((4.0..8.5).contains(&times.median),
                "median {}", times.median);
        assert!(times.max < 20.0, "max {}", times.max);
    }

    #[test]
    fn bp_write_times_match_fig7() {
        let r = quick(64, Setup::BpOnly, 4);
        let times = r.store_metrics.report(OpKind::Store, 64).times;
        // Paper Fig. 7: medians 10-15 s.
        assert!((9.0..16.0).contains(&times.median),
                "median {}", times.median);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = quick(128, Setup::SstBp, 9);
        let b = quick(128, Setup::SstBp, 9);
        assert_eq!(a.dumps, b.dumps);
        assert_eq!(a.discarded, b.discarded);
    }
}
