//! The simulated Summit substrate (S8–S10).
//!
//! The paper's evaluation ran on OLCF Summit (4608 nodes, 6 V100 per
//! node, dual-EDR Infiniband, 2.5 TiB/s Alpine GPFS). This environment
//! has none of that, so — per the substitution rule in DESIGN.md §5 —
//! the scale benchmarks run against a calibrated model:
//!
//! * [`systems`] — the Table 1 system inventory (Titan/Summit/Frontier)
//!   and the storage-requirement arithmetic.
//! * [`topology`] — nodes, GPUs, rank placement for writer/reader
//!   applications (the `jsrun` role of §4.2).
//! * [`network`] — fabric/PFS rate models with the calibration constants
//!   and their provenance (each one traces back to a number in the
//!   paper or the Summit system docs).
//! * [`des`] — a max–min fair-share ("water-filling") fluid flow
//!   simulator: transfers are flows over shared resources; event times
//!   fall out of progressive-filling rate allocation.
//!
//! What the model *does* capture: bandwidth ceilings (NIC, PFS
//! aggregate, per-node injection), sharing/contention, per-message
//! transport overheads (RDMA vs sockets), straggler tails, and the
//! backpressure semantics of the SST queue. What it does *not* capture:
//! routing detail of the fat tree, MPI collective interference, GPFS
//! metadata storms. The paper's Figs. 6–9 are dominated by the former
//! group, which is why the shapes reproduce (EXPERIMENTS.md).

pub mod des;
pub mod network;
pub mod systems;
pub mod topology;

pub use des::{FlowId, ResourceId, Sim};
pub use network::{FabricModel, TransportKind};
pub use topology::{ClusterLayout, Placement};
