//! Node topology and rank placement: the `jsrun` role.
//!
//! §4.2: "The Summit compute system hosts six GPUs per node, and the
//! surveyed setup shares them equally between simulation and analysis" —
//! placement is a *scheduling* decision the loose-coupling approach makes
//! tunable without code changes (the §4.3 GPU-share experiment).

use crate::distribution::{ReaderLayout, ReaderRank};

/// The simulated cluster: `nodes` identical nodes with `gpus_per_node`
/// GPUs each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClusterLayout {
    pub nodes: usize,
    pub gpus_per_node: usize,
}

impl ClusterLayout {
    pub fn summit(nodes: usize) -> Self {
        ClusterLayout { nodes, gpus_per_node: 6 }
    }

    pub fn hostname(&self, node: usize) -> String {
        format!("node{node:04}")
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }
}

/// A placed rank of either application.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacedRank {
    pub rank: usize,
    pub node: usize,
    /// GPU slot within the node.
    pub slot: usize,
    pub hostname: String,
}

/// Writer/reader rank placement over a cluster.
#[derive(Clone, Debug, Default)]
pub struct Placement {
    pub writers: Vec<PlacedRank>,
    pub readers: Vec<PlacedRank>,
}

impl Placement {
    /// Co-scheduled placement (§4.2): every node runs `writers_per_node`
    /// writer ranks on its first GPUs and `readers_per_node` reader ranks
    /// on the remaining ones. Panics if the node is oversubscribed.
    pub fn co_scheduled(
        cluster: ClusterLayout,
        writers_per_node: usize,
        readers_per_node: usize,
    ) -> Placement {
        assert!(
            writers_per_node + readers_per_node <= cluster.gpus_per_node,
            "{} + {} ranks > {} GPUs per node",
            writers_per_node,
            readers_per_node,
            cluster.gpus_per_node
        );
        let mut p = Placement::default();
        for node in 0..cluster.nodes {
            let hostname = cluster.hostname(node);
            for slot in 0..writers_per_node {
                p.writers.push(PlacedRank {
                    rank: node * writers_per_node + slot,
                    node,
                    slot,
                    hostname: hostname.clone(),
                });
            }
            for r in 0..readers_per_node {
                p.readers.push(PlacedRank {
                    rank: node * readers_per_node + r,
                    node,
                    slot: writers_per_node + r,
                    hostname: hostname.clone(),
                });
            }
        }
        p
    }

    /// Disjoint placement: writers on the first `writer_nodes`, readers
    /// on the rest. Used to exercise the by-hostname fallback path.
    pub fn disjoint(
        cluster: ClusterLayout,
        writer_nodes: usize,
        ranks_per_node: usize,
    ) -> Placement {
        assert!(writer_nodes <= cluster.nodes);
        assert!(ranks_per_node <= cluster.gpus_per_node);
        let mut p = Placement::default();
        for node in 0..writer_nodes {
            for slot in 0..ranks_per_node {
                p.writers.push(PlacedRank {
                    rank: node * ranks_per_node + slot,
                    node,
                    slot,
                    hostname: cluster.hostname(node),
                });
            }
        }
        for (i, node) in (writer_nodes..cluster.nodes).enumerate() {
            for slot in 0..ranks_per_node {
                p.readers.push(PlacedRank {
                    rank: i * ranks_per_node + slot,
                    node,
                    slot,
                    hostname: cluster.hostname(node),
                });
            }
        }
        p
    }

    /// The reader side as a distribution-layer [`ReaderLayout`].
    pub fn reader_layout(&self) -> ReaderLayout {
        ReaderLayout {
            ranks: self
                .readers
                .iter()
                .map(|r| ReaderRank {
                    rank: r.rank,
                    hostname: r.hostname.clone(),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn co_scheduled_3_plus_3() {
        let p = Placement::co_scheduled(ClusterLayout::summit(4), 3, 3);
        assert_eq!(p.writers.len(), 12);
        assert_eq!(p.readers.len(), 12);
        // Writer 7 = node 2, slot 1; reader 7 = node 2, slot 3+1.
        assert_eq!(p.writers[7].node, 2);
        assert_eq!(p.writers[7].slot, 1);
        assert_eq!(p.readers[7].slot, 4);
        assert_eq!(p.writers[7].hostname, p.readers[7].hostname);
    }

    #[test]
    fn gpu_share_shift_1_plus_5() {
        // §4.3: "Dedicating five GPUs on a node to GAPD and only one to
        // PIConGPU".
        let p = Placement::co_scheduled(ClusterLayout::summit(2), 1, 5);
        assert_eq!(p.writers.len(), 2);
        assert_eq!(p.readers.len(), 10);
    }

    #[test]
    #[should_panic]
    fn oversubscription_panics() {
        Placement::co_scheduled(ClusterLayout::summit(1), 4, 3);
    }

    #[test]
    fn disjoint_nodes_have_no_overlap() {
        let p = Placement::disjoint(ClusterLayout::summit(6), 4, 6);
        assert_eq!(p.writers.len(), 24);
        assert_eq!(p.readers.len(), 12);
        let wh: std::collections::BTreeSet<_> =
            p.writers.iter().map(|w| &w.hostname).collect();
        let rh: std::collections::BTreeSet<_> =
            p.readers.iter().map(|r| &r.hostname).collect();
        assert!(wh.is_disjoint(&rh));
    }

    #[test]
    fn reader_layout_conversion() {
        let p = Placement::co_scheduled(ClusterLayout::summit(2), 3, 3);
        let l = p.reader_layout();
        assert_eq!(l.len(), 6);
        assert_eq!(l.ranks[4].hostname, "node0001");
    }
}
