//! Max–min fair-share fluid-flow discrete-event simulator (S10).
//!
//! Transfers are *flows*: a byte count moving across a set of shared
//! *resources* (a node NIC, the PFS aggregate, a per-connection cap).
//! Between events, every flow proceeds at the rate assigned by
//! progressive filling (water-filling): repeatedly find the most
//! contended resource, give each unfixed flow crossing it an equal share,
//! fix those flows, and continue — the standard fluid approximation of
//! TCP/fabric fair sharing.
//!
//! The recompute is O(rounds × (R + F)) with per-resource active
//! counters, which keeps 512-node × multi-flow benchmark runs in the
//! milliseconds-per-simulated-dump range (see EXPERIMENTS.md §Perf).
//!
//! Timers let benchmark harnesses model compute phases and output
//! pacing; flows model the IO. The harness alternates:
//! `next_event()` → react (start flows / timers) → repeat.

use std::collections::BinaryHeap;

/// Handle to a resource.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub usize);

/// Handle to a flow.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FlowId(pub usize);

/// Handle to a timer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TimerId(pub usize);

/// An event returned by [`Sim::next_event`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A flow transferred its last byte at the given time.
    FlowDone { id: FlowId, at: f64 },
    /// A timer fired.
    Timer { id: TimerId, at: f64 },
}

struct Resource {
    capacity: f64,
    /// Scratch for water-filling.
    used: f64,
    unfixed: usize,
    saturated: bool,
}

struct Flow {
    remaining: f64,
    resources: Vec<ResourceId>,
    /// Per-flow rate cap (straggler factor / connection limit folded in).
    cap: f64,
    rate: f64,
    done: bool,
    /// Caller tag for bookkeeping.
    pub tag: u64,
    started_at: f64,
}

#[derive(Clone, Copy, PartialEq)]
struct TimerEntry {
    at: f64,
    id: usize,
}

impl Eq for TimerEntry {}

impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap by time.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.id.cmp(&self.id))
    }
}

impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The simulator.
pub struct Sim {
    time: f64,
    resources: Vec<Resource>,
    flows: Vec<Flow>,
    active: Vec<usize>,
    timers: BinaryHeap<TimerEntry>,
    next_timer: usize,
    rates_dirty: bool,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Sim {
        Sim {
            time: 0.0,
            resources: Vec::new(),
            flows: Vec::new(),
            active: Vec::new(),
            timers: BinaryHeap::new(),
            next_timer: 0,
            rates_dirty: false,
        }
    }

    pub fn time(&self) -> f64 {
        self.time
    }

    /// Register a shared resource with `capacity` bytes/s.
    pub fn add_resource(&mut self, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0);
        self.resources.push(Resource {
            capacity,
            used: 0.0,
            unfixed: 0,
            saturated: false,
        });
        ResourceId(self.resources.len() - 1)
    }

    /// Start a flow of `bytes` over `resources`, rate-capped at `cap`
    /// bytes/s (use `f64::INFINITY` for none). `tag` is returned to the
    /// caller for identification; `bytes` may be pre-inflated by a
    /// straggler factor.
    pub fn add_flow(
        &mut self,
        bytes: f64,
        resources: Vec<ResourceId>,
        cap: f64,
        tag: u64,
    ) -> FlowId {
        assert!(bytes >= 0.0);
        assert!(
            !resources.is_empty() || cap.is_finite(),
            "flow needs at least one resource or a finite cap"
        );
        let id = self.flows.len();
        self.flows.push(Flow {
            remaining: bytes.max(1e-9),
            resources,
            cap,
            rate: 0.0,
            done: false,
            tag,
            started_at: self.time,
        });
        self.active.push(id);
        self.rates_dirty = true;
        FlowId(id)
    }

    /// Schedule a timer at absolute time `at` (>= now).
    pub fn add_timer(&mut self, at: f64) -> TimerId {
        let id = self.next_timer;
        self.next_timer += 1;
        self.timers.push(TimerEntry { at: at.max(self.time), id });
        TimerId(id)
    }

    pub fn flow_tag(&self, id: FlowId) -> u64 {
        self.flows[id.0].tag
    }

    /// Time the flow started (for perceived-throughput accounting).
    pub fn flow_started_at(&self, id: FlowId) -> f64 {
        self.flows[id.0].started_at
    }

    pub fn active_flows(&self) -> usize {
        self.active.len()
    }

    /// Water-filling rate allocation over the active flows.
    fn recompute_rates(&mut self) {
        for r in self.resources.iter_mut() {
            r.used = 0.0;
            r.unfixed = 0;
            r.saturated = false;
        }
        let mut unfixed: Vec<usize> = self.active.clone();
        for &f in &unfixed {
            for rid in &self.flows[f].resources {
                self.resources[rid.0].unfixed += 1;
            }
            self.flows[f].rate = 0.0;
        }

        // Progressive filling. Each round either saturates a resource or
        // fixes all flows capped below the current water level, so the
        // round count is bounded by #resources + #distinct cap waves.
        while !unfixed.is_empty() {
            // Fair share currently offered by each unsaturated resource.
            let mut min_share = f64::INFINITY;
            for r in self.resources.iter() {
                if !r.saturated && r.unfixed > 0 {
                    let share = (r.capacity - r.used) / r.unfixed as f64;
                    if share < min_share {
                        min_share = share;
                    }
                }
            }
            if !min_share.is_finite() {
                // Remaining flows cross no constrained resource: they run
                // at their caps.
                for &f in &unfixed {
                    let rate = self.flows[f].cap;
                    assert!(rate.is_finite(),
                            "uncapped flow without resources");
                    self.flows[f].rate = rate;
                }
                break;
            }

            // Wave 1: fix all flows whose cap is below the water level.
            let mut fixed_any = false;
            let mut still: Vec<usize> = Vec::with_capacity(unfixed.len());
            for &f in &unfixed {
                if self.flows[f].cap <= min_share {
                    let rate = self.flows[f].cap;
                    self.flows[f].rate = rate;
                    for rid in &self.flows[f].resources {
                        let r = &mut self.resources[rid.0];
                        r.used += rate;
                        r.unfixed -= 1;
                    }
                    fixed_any = true;
                } else {
                    still.push(f);
                }
            }
            unfixed = still;
            if fixed_any {
                continue;
            }

            // Wave 2: saturate the bottleneck resource(s). ALL resources
            // tied at the minimum share saturate together — with
            // symmetric topologies (hundreds of identical node NICs)
            // this is the difference between O(1) and O(R) rounds.
            let mut best = f64::INFINITY;
            for r in self.resources.iter() {
                if !r.saturated && r.unfixed > 0 {
                    let share = (r.capacity - r.used) / r.unfixed as f64;
                    if share < best {
                        best = share;
                    }
                }
            }
            debug_assert!(best.is_finite(),
                          "no bottleneck but flows unfixed");
            let eps = best.abs() * 1e-9 + 1e-15;
            let mut newly_saturated = vec![false; self.resources.len()];
            for (i, r) in self.resources.iter_mut().enumerate() {
                if !r.saturated && r.unfixed > 0 {
                    let share = (r.capacity - r.used) / r.unfixed as f64;
                    if share <= best + eps {
                        r.saturated = true;
                        newly_saturated[i] = true;
                    }
                }
            }
            let mut still = Vec::with_capacity(unfixed.len());
            for &f in &unfixed {
                let on_bottleneck = self.flows[f]
                    .resources
                    .iter()
                    .any(|r| newly_saturated[r.0]);
                if on_bottleneck {
                    self.flows[f].rate = best;
                    for rid in &self.flows[f].resources {
                        if !newly_saturated[rid.0] {
                            let r = &mut self.resources[rid.0];
                            r.used += best;
                            r.unfixed -= 1;
                        }
                    }
                } else {
                    still.push(f);
                }
            }
            for (i, r) in self.resources.iter_mut().enumerate() {
                if newly_saturated[i] {
                    r.used = r.capacity;
                    r.unfixed = 0;
                }
            }
            unfixed = still;
        }
        self.rates_dirty = false;
    }

    /// Advance to and return the next event; `None` when idle.
    pub fn next_event(&mut self) -> Option<Event> {
        if self.rates_dirty {
            self.recompute_rates();
        }
        // Next flow completion under current rates.
        let mut next_flow: Option<(f64, usize)> = None;
        for &f in &self.active {
            let fl = &self.flows[f];
            if fl.rate <= 0.0 {
                continue;
            }
            let eta = self.time + fl.remaining / fl.rate;
            if next_flow.map(|(t, _)| eta < t).unwrap_or(true) {
                next_flow = Some((eta, f));
            }
        }
        let next_timer = self.timers.peek().copied();

        match (next_flow, next_timer) {
            (None, None) => None,
            (Some((tf, f)), None) => Some(self.finish_flow(tf, f)),
            (None, Some(te)) => {
                self.timers.pop();
                self.advance(te.at);
                Some(Event::Timer { id: TimerId(te.id), at: te.at })
            }
            (Some((tf, f)), Some(te)) => {
                if te.at <= tf {
                    self.timers.pop();
                    self.advance(te.at);
                    Some(Event::Timer { id: TimerId(te.id), at: te.at })
                } else {
                    Some(self.finish_flow(tf, f))
                }
            }
        }
    }

    fn advance(&mut self, to: f64) {
        let dt = to - self.time;
        debug_assert!(dt >= -1e-9, "time going backwards: {dt}");
        if dt > 0.0 {
            for &f in &self.active {
                let fl = &mut self.flows[f];
                fl.remaining -= fl.rate * dt;
            }
            self.time = to;
        }
    }

    fn finish_flow(&mut self, at: f64, f: usize) -> Event {
        self.advance(at);
        self.flows[f].done = true;
        self.flows[f].remaining = 0.0;
        self.active.retain(|&x| x != f);
        self.rates_dirty = true;
        Event::FlowDone { id: FlowId(f), at }
    }

    /// Run until no events remain; returns the number processed.
    pub fn drain(&mut self) -> usize {
        let mut n = 0;
        while self.next_event().is_some() {
            n += 1;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_single_resource() {
        let mut sim = Sim::new();
        let r = sim.add_resource(100.0);
        let f = sim.add_flow(1000.0, vec![r], f64::INFINITY, 7);
        match sim.next_event() {
            Some(Event::FlowDone { id, at }) => {
                assert_eq!(id, f);
                assert!((at - 10.0).abs() < 1e-9);
                assert_eq!(sim.flow_tag(id), 7);
            }
            other => panic!("{other:?}"),
        }
        assert!(sim.next_event().is_none());
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = Sim::new();
        let r = sim.add_resource(100.0);
        sim.add_flow(500.0, vec![r], f64::INFINITY, 1);
        sim.add_flow(1000.0, vec![r], f64::INFINITY, 2);
        // Both run at 50 until flow 1 finishes at t=10; flow 2 then has
        // 500 left at rate 100 -> finishes at t=15.
        match sim.next_event().unwrap() {
            Event::FlowDone { at, .. } => assert!((at - 10.0).abs() < 1e-9),
            e => panic!("{e:?}"),
        }
        match sim.next_event().unwrap() {
            Event::FlowDone { at, .. } => assert!((at - 15.0).abs() < 1e-9),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn per_flow_cap_binds() {
        let mut sim = Sim::new();
        let r = sim.add_resource(100.0);
        sim.add_flow(100.0, vec![r], 10.0, 1); // capped at 10
        sim.add_flow(900.0, vec![r], f64::INFINITY, 2); // gets 90
        match sim.next_event().unwrap() {
            Event::FlowDone { at, id } => {
                // Both at t=10: capped flow 100/10, big flow 900/90.
                assert!((at - 10.0).abs() < 1e-9, "{at} {id:?}");
            }
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn multi_resource_bottleneck() {
        // Flow A crosses r1(100) and r2(30); B crosses r2 only.
        // Water level on r2 = 15 each; A is limited to 15, B gets
        // r2 leftover? No: both on r2 -> 15 each; r1 unsaturated.
        let mut sim = Sim::new();
        let r1 = sim.add_resource(100.0);
        let r2 = sim.add_resource(30.0);
        sim.add_flow(150.0, vec![r1, r2], f64::INFINITY, 1);
        sim.add_flow(150.0, vec![r2], f64::INFINITY, 2);
        match sim.next_event().unwrap() {
            Event::FlowDone { at, .. } => {
                assert!((at - 10.0).abs() < 1e-9, "{at}");
            }
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn freed_capacity_redistributes() {
        let mut sim = Sim::new();
        let r = sim.add_resource(100.0);
        sim.add_flow(100.0, vec![r], f64::INFINITY, 1);
        sim.add_flow(5000.0, vec![r], f64::INFINITY, 2);
        let Event::FlowDone { at: t1, .. } = sim.next_event().unwrap()
        else { panic!() };
        assert!((t1 - 2.0).abs() < 1e-9);
        // Flow 2: transferred 100 in 2s, 4900 left at rate 100 -> t=51.
        let Event::FlowDone { at: t2, .. } = sim.next_event().unwrap()
        else { panic!() };
        assert!((t2 - 51.0).abs() < 1e-9, "{t2}");
    }

    #[test]
    fn timers_interleave_with_flows() {
        let mut sim = Sim::new();
        let r = sim.add_resource(10.0);
        sim.add_flow(100.0, vec![r], f64::INFINITY, 1); // done at 10
        let t = sim.add_timer(4.0);
        match sim.next_event().unwrap() {
            Event::Timer { id, at } => {
                assert_eq!(id, t);
                assert!((at - 4.0).abs() < 1e-12);
            }
            e => panic!("{e:?}"),
        }
        // Start another flow mid-way: remaining 60 shared at 5/s each.
        sim.add_flow(30.0, vec![r], f64::INFINITY, 2); // done at 4+6=10
        let Event::FlowDone { at, .. } = sim.next_event().unwrap()
        else { panic!() };
        assert!((at - 10.0).abs() < 1e-9, "{at}");
    }

    #[test]
    fn aggregate_plus_per_node_resources_contention() {
        // 8 nodes with per-node cap 5, aggregate cap 20: each flow gets
        // 20/8 = 2.5 (aggregate-bound); with 2 nodes, each gets 5
        // (node-bound). This is exactly the PFS regime change between 64
        // and 512 nodes.
        for (nodes, want_rate) in [(8, 2.5), (2, 5.0)] {
            let mut sim = Sim::new();
            let agg = sim.add_resource(20.0);
            let mut flows = Vec::new();
            for _ in 0..nodes {
                let nic = sim.add_resource(5.0);
                flows.push(sim.add_flow(
                    100.0, vec![nic, agg], f64::INFINITY, 0));
            }
            let Event::FlowDone { at, .. } = sim.next_event().unwrap()
            else { panic!() };
            assert!((at - 100.0 / want_rate).abs() < 1e-6,
                    "nodes={nodes} at={at}");
        }
    }

    #[test]
    fn drain_counts_all_events() {
        let mut sim = Sim::new();
        let r = sim.add_resource(1.0);
        for i in 0..5 {
            sim.add_flow(1.0 + i as f64, vec![r], f64::INFINITY, i);
        }
        sim.add_timer(100.0);
        assert_eq!(sim.drain(), 6);
        assert!(sim.next_event().is_none());
    }

    #[test]
    fn zero_byte_flow_completes_immediately_enough() {
        let mut sim = Sim::new();
        let r = sim.add_resource(1e9);
        sim.add_flow(0.0, vec![r], f64::INFINITY, 1);
        let Event::FlowDone { at, .. } = sim.next_event().unwrap()
        else { panic!() };
        assert!(at < 1e-6);
    }
}
