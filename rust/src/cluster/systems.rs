//! System inventory behind Table 1: OLCF Titan → Summit → Frontier,
//! and the storage-requirement arithmetic the table's last column shows.

use crate::util::bytes::{GIB, PIB, TIB};

/// One HPC system's headline numbers.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemSpec {
    pub name: &'static str,
    pub year: u32,
    /// Peak compute in PFlop/s.
    pub compute_pflops: f64,
    /// Parallel-filesystem aggregate bandwidth in bytes/s.
    /// For planned systems a (min, max) range.
    pub pfs_bandwidth: (f64, f64),
    /// PFS capacity in bytes (min, max).
    pub pfs_capacity: (f64, f64),
    pub nodes: u64,
    pub gpus_per_node: u64,
    /// GPU memory per device, bytes.
    pub gpu_mem: u64,
}

/// OLCF Titan (2013): Tesla K20X, Atlas Lustre.
pub const TITAN: SystemSpec = SystemSpec {
    name: "Titan",
    year: 2013,
    compute_pflops: 27.0,
    pfs_bandwidth: (1.0 * TIB as f64, 1.0 * TIB as f64),
    pfs_capacity: (27.0 * PIB as f64, 27.0 * PIB as f64),
    nodes: 18_688,
    gpus_per_node: 1,
    gpu_mem: 6 * GIB,
};

/// OLCF Summit (2018): 6x Tesla V100 (16 GiB HBM2), Alpine GPFS.
pub const SUMMIT: SystemSpec = SystemSpec {
    name: "Summit",
    year: 2018,
    compute_pflops: 200.0,
    pfs_bandwidth: (2.5 * TIB as f64, 2.5 * TIB as f64),
    pfs_capacity: (250.0 * PIB as f64, 250.0 * PIB as f64),
    nodes: 4_608,
    gpus_per_node: 6,
    gpu_mem: 16 * GIB,
};

/// OLCF Frontier (planned 2021 at the time of the paper): ranges as the
/// paper quotes them.
pub const FRONTIER: SystemSpec = SystemSpec {
    name: "Frontier",
    year: 2021,
    compute_pflops: 1_500.0,
    pfs_bandwidth: (5.0 * TIB as f64, 10.0 * TIB as f64),
    pfs_capacity: (500.0 * PIB as f64, 1000.0 * PIB as f64),
    nodes: 9_408,
    gpus_per_node: 4,
    // MI250X: 128 GiB per module; the paper's 80-100 PiB storage-need
    // column implies ~ 1.6-2.0 PiB of aggregate GPU memory.
    gpu_mem: 128 * GIB,
};

impl SystemSpec {
    /// Aggregate GPU memory of the whole machine, bytes.
    pub fn total_gpu_memory(&self) -> u64 {
        self.nodes * self.gpus_per_node * self.gpu_mem
    }

    /// Table 1, last column: storage needed by a full-scale run that
    /// dumps all GPU memory `dumps` times.
    pub fn storage_requirement(&self, dumps: u64) -> u64 {
        self.total_gpu_memory() * dumps
    }

    /// §1.1: theoretical max PFS throughput per GPU at full scale —
    /// 56 MB/s on Titan, ~95 MB/s on Summit.
    pub fn pfs_share_per_gpu(&self) -> f64 {
        self.pfs_bandwidth.0 / (self.nodes * self.gpus_per_node) as f64
    }

    /// Compute-to-bandwidth growth factors between systems (§1.1's
    /// argument that storage scaling falls behind compute scaling).
    pub fn compute_factor_over(&self, other: &SystemSpec) -> f64 {
        self.compute_pflops / other.compute_pflops
    }

    pub fn bandwidth_factor_over(&self, other: &SystemSpec) -> (f64, f64) {
        (
            self.pfs_bandwidth.0 / other.pfs_bandwidth.1,
            self.pfs_bandwidth.1 / other.pfs_bandwidth.0,
        )
    }
}

/// All three systems, Table 1 order.
pub fn table1_systems() -> [&'static SystemSpec; 3] {
    [&TITAN, &SUMMIT, &FRONTIER]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summit_storage_requirement_matches_paper() {
        // Paper: 21.1 PiB for 50 dumps of all GPU memory.
        let req = SUMMIT.storage_requirement(50) as f64 / PIB as f64;
        assert!((req - 21.1).abs() < 0.3, "got {req} PiB");
    }

    #[test]
    fn titan_storage_requirement_matches_paper() {
        // Paper: 5.3 PiB.
        let req = TITAN.storage_requirement(50) as f64 / PIB as f64;
        assert!((req - 5.3).abs() < 0.2, "got {req} PiB");
    }

    #[test]
    fn per_gpu_pfs_share_matches_paper() {
        // Paper §1.1: 56 MB/s on Titan, 95 MB/s on Summit.
        let titan = TITAN.pfs_share_per_gpu() / (1 << 20) as f64;
        assert!((titan - 56.0).abs() < 6.0, "titan {titan} MiB/s");
        let summit = SUMMIT.pfs_share_per_gpu() / (1 << 20) as f64;
        assert!((summit - 95.0).abs() < 6.0, "summit {summit} MiB/s");
    }

    #[test]
    fn growth_factors_match_paper() {
        // Compute: ~7.4x Titan->Summit, >7.5x Summit->Frontier.
        let c1 = SUMMIT.compute_factor_over(&TITAN);
        assert!((c1 - 7.4).abs() < 0.1, "{c1}");
        assert!(FRONTIER.compute_factor_over(&SUMMIT) >= 7.5);
        // Bandwidth: only 2.5x Titan->Summit, 2-4x Summit->Frontier.
        let (blo, bhi) = SUMMIT.bandwidth_factor_over(&TITAN);
        assert!((blo - 2.5).abs() < 0.01 && (bhi - 2.5).abs() < 0.01);
        let (flo, fhi) = FRONTIER.bandwidth_factor_over(&SUMMIT);
        assert!(flo >= 2.0 && fhi <= 4.0);
    }
}
