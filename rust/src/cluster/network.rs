//! Fabric / PFS rate models with calibration provenance.
//!
//! Every constant here traces to a number in the paper or in public
//! Summit documentation; `benches/` builds its simulated experiments
//! exclusively from these. Calibration targets (see EXPERIMENTS.md):
//!
//! * Summit node NIC: dual-rail EDR Infiniband, 25 GB/s injection
//!   (≈ 23.3 GiB/s) — Vazhkudai et al. 2018.
//! * Alpine PFS: 2.5 TiB/s aggregate (paper Table 1); per-node GPFS
//!   client throughput ~5 GiB/s (observed BP-only per-node rates at low
//!   scale in Fig. 6: ~0.3 TiB/s over 64 nodes).
//! * Paper Fig. 6: BP-only write times median 10–15 s with outliers to
//!   45 s at ≥256 nodes; streaming loads median 5–7 s, worst ~9 s.
//! * Paper Fig. 8: RDMA ~5.1 TiB/s at 512 nodes for the 3+3 pipeline;
//!   sockets ~1 TiB/s; binpacking-without-topology ~3.7x worse than the
//!   topology-aware strategies on RDMA and catastrophically worse on
//!   sockets (its fully-connected m×n mesh multiplies per-message
//!   overhead).
//! * §4.3: "no measurable improvement" of node-local streaming over
//!   cross-node streaming — SST's data plane goes through the NIC
//!   stack either way (no IPC shortcut), so the model charges
//!   *intra-node* streaming to the same NIC resource as inter-node.

use crate::util::bytes::{GIB, KIB, TIB};
use crate::util::rng::Rng;

/// Data-plane transport of the SST engine (§2.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransportKind {
    /// libfabric/Infiniband RDMA.
    Rdma,
    /// WAN/sockets (TCP).
    Tcp,
}

/// Per-connection transport parameters.
#[derive(Clone, Copy, Debug)]
pub struct TransportModel {
    /// Per-connection streaming bandwidth cap, bytes/s.
    pub per_conn_bandwidth: f64,
    /// Fixed cost per chunk request/response pair, seconds. This is the
    /// term that punishes fully-connected m×n patterns on sockets.
    pub per_message_overhead: f64,
    /// One-time connection establishment, seconds.
    pub setup_latency: f64,
    /// Per-step rendezvous cost with each *non-co-located* writer a
    /// reader exchanges data with: SST per-pair connection resources +
    /// per-step metadata sync. This is the calibrated term behind the
    /// paper's §4.3 finding that "the number of communication partners"
    /// drives strategy (2)'s poor performance.
    pub remote_rendezvous: f64,
}

impl TransportKind {
    pub fn model(self) -> TransportModel {
        match self {
            // RDMA: zero-copy, kernel-bypass. A single EDR rail sustains
            // ~12.2 GiB/s; request latency is microseconds.
            TransportKind::Rdma => TransportModel {
                per_conn_bandwidth: 12.2 * GIB as f64,
                per_message_overhead: 15e-6,
                setup_latency: 1e-3,
                remote_rendezvous: 0.7,
            },
            // Sockets: protocol + copy overhead caps a single stream far
            // below line rate (the paper's WAN result: 400-995 GiB/s
            // aggregate over 256+ nodes => ~1-2 GiB/s per instance), and
            // every request costs a software round trip.
            TransportKind::Tcp => TransportModel {
                per_conn_bandwidth: 1.6 * GIB as f64,
                per_message_overhead: 2.5e-3,
                setup_latency: 30e-3,
                remote_rendezvous: 12.0,
            },
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            TransportKind::Rdma => "RDMA",
            TransportKind::Tcp => "sockets",
        }
    }
}

/// Parallel-filesystem model (Alpine).
#[derive(Clone, Copy, Debug)]
pub struct PfsModel {
    /// Aggregate bandwidth ceiling, bytes/s.
    pub aggregate_bandwidth: f64,
    /// Per-node GPFS client ceiling, bytes/s.
    pub per_node_bandwidth: f64,
    /// Fixed per-write-op metadata/open cost at the 64-node baseline,
    /// seconds.
    pub metadata_latency: f64,
    /// GPFS metadata contention grows super-linearly with concurrent
    /// clients (token/lock traffic): latency scales with
    /// `(nodes/64)^exponent`. Calibrated so that the per-op cost stays
    /// sub-second at 64 nodes but reaches several seconds at 512 — the
    /// regime in which the paper's SST+BP setup starts dropping dumps
    /// and BP-only write outliers reach ~45 s (Fig. 7).
    pub metadata_scale_exponent: f64,
}

impl Default for PfsModel {
    fn default() -> Self {
        PfsModel {
            aggregate_bandwidth: 2.5 * TIB as f64,
            per_node_bandwidth: 5.0 * GIB as f64,
            metadata_latency: 0.35,
            metadata_scale_exponent: 1.7,
        }
    }
}

impl PfsModel {
    /// Per-write-op metadata cost at a given scale.
    pub fn metadata_latency_at(&self, nodes: usize) -> f64 {
        let x = (nodes.max(1) as f64 / 64.0).max(1.0);
        self.metadata_latency * x.powf(self.metadata_scale_exponent)
    }
}

/// The whole fabric model.
#[derive(Clone, Copy, Debug)]
pub struct FabricModel {
    /// Per-node NIC injection == ejection bandwidth, bytes/s.
    pub nic_bandwidth: f64,
    /// Ingestion ceiling of one `openpmd-pipe` process (single-process
    /// deserialize + staging copies): what actually bounds the §4.1
    /// streaming phase, not the NIC. Calibrated to the paper's 4.15
    /// TiB/s over 3072 producers (~1.4 GiB/s per producer with 6
    /// producers per pipe).
    pub pipe_ingest_bandwidth: f64,
    /// Host-side staging-copy bandwidth: producer-side cost of handing
    /// a step to the SST queue (the small "raw IO" share of §4.1).
    pub staging_copy_bandwidth: f64,
    pub pfs: PfsModel,
}

impl Default for FabricModel {
    fn default() -> Self {
        FabricModel {
            nic_bandwidth: 23.3 * GIB as f64,
            pipe_ingest_bandwidth: 8.5 * GIB as f64,
            staging_copy_bandwidth: 13.0 * GIB as f64,
            pfs: PfsModel::default(),
        }
    }
}

impl FabricModel {
    pub fn summit() -> Self {
        Self::default()
    }
}

/// Straggler model: multiplicative log-normal slow-down factors for IO
/// operations, with a heavier tail at larger scale (shared-resource
/// interference grows with participant count — the paper's "general
/// trend is the increasing number of outliers at 256 nodes").
#[derive(Clone, Copy, Debug)]
pub struct StragglerModel {
    /// Sigma at the 64-node baseline.
    pub base_sigma: f64,
    /// Added sigma per doubling beyond 64 nodes.
    pub sigma_per_doubling: f64,
}

impl StragglerModel {
    /// PFS writes: Fig. 7 shows medians 10-15 s with a 45 s worst case.
    pub fn pfs() -> Self {
        StragglerModel { base_sigma: 0.13, sigma_per_doubling: 0.05 }
    }

    /// Streaming transfers: tighter (5-7 s medians, worst ~9 s).
    pub fn streaming() -> Self {
        StragglerModel { base_sigma: 0.05, sigma_per_doubling: 0.03 }
    }

    pub fn sigma(&self, nodes: usize) -> f64 {
        let doublings = ((nodes.max(1) as f64) / 64.0).log2().max(0.0);
        self.base_sigma + self.sigma_per_doubling * doublings
    }

    /// Draw a slow-down factor (>= ~1): median 1.0, log-normal tail.
    pub fn draw(&self, nodes: usize, rng: &mut Rng) -> f64 {
        rng.lognormal(1.0, self.sigma(nodes)).max(0.5)
    }
}

/// Convenience: the per-request overhead of loading `selection_bytes`
/// through `partners` connections under a transport (latency term of the
/// perceived-throughput definition in §4.1).
pub fn request_overhead(
    transport: TransportKind,
    partners: usize,
    requests: usize,
) -> f64 {
    let m = transport.model();
    // Setup is amortized over a stream's lifetime; we charge it once per
    // partner per *step* to stay conservative.
    m.setup_latency * 0.0 + m.per_message_overhead * requests as f64
        + 0.0 * partners as f64
}

/// Effective message sizes: SST moves data in chunk-granular messages;
/// messages below this size are dominated by the per-message term.
pub const MIN_MESSAGE: u64 = 64 * KIB;

/// Typical PIConGPU output sizes from the paper.
pub mod workload {
    use super::*;

    /// §4.1: 9.14 GiB per data output step and parallel process.
    pub const BYTES_PER_PRODUCER_FULL: u64 =
        (9.14 * GIB as f64) as u64;

    /// §4.2: particle-only output, ~3.1 GiB per process.
    pub const BYTES_PER_PRODUCER_PARTICLES: u64 =
        (3.1 * GIB as f64) as u64;

    /// Kelvin-Helmholtz production run: compute time per 100-step output
    /// period, seconds. Calibrated so BP-only completes ~22 dumps and
    /// SST+BP ~33 dumps in 15 minutes at 64 nodes (§4.1).
    pub const COMPUTE_PER_OUTPUT_PERIOD: f64 = 25.5;

    /// §4.3: GAPD needs ~5 min 15 s per scatter plot with 3 GPUs/node...
    pub const GAPD_COMPUTE_3GPU: f64 = 315.0;
    /// ...and ~1 minute with 5 GPUs/node.
    pub const GAPD_COMPUTE_5GPU: f64 = 63.0;

    /// §4.3: PIConGPU simulation step rate in the 3+3 setup — a scatter
    /// plot every 2000 steps without blocking means ~2000 steps take
    /// >= GAPD_COMPUTE_3GPU: ~0.157 s per simulation step.
    pub const SIM_SECONDS_PER_STEP: f64 = GAPD_COMPUTE_3GPU / 2000.0;

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rdma_beats_tcp_everywhere() {
        let r = TransportKind::Rdma.model();
        let t = TransportKind::Tcp.model();
        assert!(r.per_conn_bandwidth > t.per_conn_bandwidth);
        assert!(r.per_message_overhead < t.per_message_overhead);
        assert!(r.setup_latency < t.setup_latency);
        assert!(r.remote_rendezvous < t.remote_rendezvous);
    }

    #[test]
    fn metadata_latency_scales_superlinearly() {
        let p = PfsModel::default();
        let at64 = p.metadata_latency_at(64);
        let at512 = p.metadata_latency_at(512);
        assert_eq!(at64, p.metadata_latency);
        assert_eq!(p.metadata_latency_at(8), p.metadata_latency);
        assert!(at512 > 8.0 * at64, "{at512} vs {at64}");
        assert!(at512 < 16.0, "implausible {at512}");
    }

    #[test]
    fn straggler_sigma_grows_with_scale() {
        let m = StragglerModel::pfs();
        assert!(m.sigma(512) > m.sigma(256));
        assert!(m.sigma(256) > m.sigma(64));
        assert_eq!(m.sigma(64), m.base_sigma);
        assert_eq!(m.sigma(1), m.base_sigma); // below baseline clamps
    }

    #[test]
    fn straggler_draws_are_heavy_tailed_but_bounded_below() {
        let m = StragglerModel::pfs();
        let mut rng = Rng::new(1);
        let draws: Vec<f64> =
            (0..20_000).map(|_| m.draw(512, &mut rng)).collect();
        assert!(draws.iter().all(|&x| x >= 0.5));
        let med = crate::util::stats::median(&draws);
        assert!((med - 1.0).abs() < 0.05, "median {med}");
        let p_max = draws.iter().cloned().fold(0.0, f64::max);
        assert!(p_max > 2.5, "tail too light: {p_max}");
        assert!(p_max < 20.0, "tail implausible: {p_max}");
    }

    #[test]
    fn pfs_model_matches_table1() {
        let p = PfsModel::default();
        assert_eq!(p.aggregate_bandwidth, 2.5 * TIB as f64);
        // 64 nodes at the per-node cap stay well under the aggregate.
        assert!(64.0 * p.per_node_bandwidth < p.aggregate_bandwidth);
        // 512 nodes at the per-node cap reach it => contention regime.
        assert!(512.0 * p.per_node_bandwidth >= p.aggregate_bandwidth);
    }

    #[test]
    fn request_overhead_scales_with_messages() {
        let a = request_overhead(TransportKind::Tcp, 3, 10);
        let b = request_overhead(TransportKind::Tcp, 3, 1000);
        assert!(b > a * 50.0);
        assert!(request_overhead(TransportKind::Rdma, 3, 1000) < b / 50.0);
    }

    #[test]
    fn workload_constants_sane() {
        assert!(workload::BYTES_PER_PRODUCER_FULL
                > workload::BYTES_PER_PRODUCER_PARTICLES);
        assert!((workload::SIM_SECONDS_PER_STEP - 0.1575).abs() < 1e-3);
    }
}
