//! Energy-spectrum binning: the "filter and bin" analysis of Fig. 2.
//!
//! Computes a weighted kinetic-energy histogram of the particle stream
//! via the `binning` artifact (Pallas one-hot matmul histogram), with a
//! pure-rust fallback. Constants mirror python/compile/model.py.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{Exec, Runtime};

pub const E_MIN: f32 = 0.0;
pub const E_MAX: f32 = 8.0;
pub const N_BINS: usize = 256;
/// Batch size baked into the artifact (aot.py HIST_SAMPLES).
pub const BATCH: usize = 16384;

/// Accumulating energy-spectrum analyzer.
pub struct EnergySpectrum {
    exec: Option<Arc<Exec>>,
    bins: Vec<f64>,
    pub samples_seen: u64,
}

impl EnergySpectrum {
    pub fn new(runtime: Option<&Runtime>) -> Result<Self> {
        let exec = match runtime {
            Some(rt) => Some(rt.get("binning")?),
            None => None,
        };
        Ok(EnergySpectrum {
            exec,
            bins: vec![0.0; N_BINS],
            samples_seen: 0,
        })
    }

    /// Feed momenta (interleaved [n,3]) and weights (n).
    pub fn consume(&mut self, mom: &[f32], w: &[f32]) -> Result<()> {
        assert_eq!(mom.len(), w.len() * 3);
        let n = w.len();
        let mut i = 0;
        while i < n {
            let take = (n - i).min(BATCH);
            match self.exec.clone() {
                Some(exec) => self.batch_pjrt(
                    &exec,
                    &mom[i * 3..(i + take) * 3],
                    &w[i..i + take],
                )?,
                None => self.batch_fallback(
                    &mom[i * 3..(i + take) * 3],
                    &w[i..i + take],
                ),
            }
            self.samples_seen += take as u64;
            i += take;
        }
        Ok(())
    }

    fn batch_pjrt(&mut self, exec: &Exec, mom: &[f32], w: &[f32])
        -> Result<()>
    {
        let take = w.len();
        let mut mom_b = vec![0.0f32; BATCH * 3];
        let mut w_b = vec![0.0f32; BATCH];
        mom_b[..take * 3].copy_from_slice(mom);
        w_b[..take].copy_from_slice(w);
        let out = exec.run_f32(&[&mom_b, &w_b])?;
        for (acc, v) in self.bins.iter_mut().zip(&out[0]) {
            *acc += *v as f64;
        }
        // Zero-weight padding lands in bin 0 with weight 0: no effect.
        Ok(())
    }

    fn batch_fallback(&mut self, mom: &[f32], w: &[f32]) {
        let width = (E_MAX - E_MIN) / N_BINS as f32;
        for (j, &wj) in w.iter().enumerate() {
            let e = 0.5
                * (mom[j * 3].powi(2)
                    + mom[j * 3 + 1].powi(2)
                    + mom[j * 3 + 2].powi(2));
            let idx = (((e - E_MIN) / width).floor() as i64)
                .clamp(0, N_BINS as i64 - 1) as usize;
            self.bins[idx] += wj as f64;
        }
    }

    pub fn spectrum(&self) -> &[f64] {
        &self.bins
    }

    pub fn total_weight(&self) -> f64 {
        self.bins.iter().sum()
    }

    pub fn merge(&mut self, other: &EnergySpectrum) {
        self.absorb_bins(other.spectrum(), other.samples_seen);
    }

    /// Merge raw accumulated bins (from a worker that cannot move its
    /// PJRT handles across threads).
    pub fn absorb_bins(&mut self, bins: &[f64], samples: u64) {
        assert_eq!(bins.len(), self.bins.len());
        for (a, b) in self.bins.iter_mut().zip(bins) {
            *a += *b;
        }
        self.samples_seen += samples;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn total_weight_conserved_fallback() {
        let mut rng = Rng::new(0);
        let n = 1000;
        let mom: Vec<f32> =
            (0..n * 3).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..n).map(|_| 0.5 + rng.f32()).collect();
        let mut s = EnergySpectrum::new(None).unwrap();
        s.consume(&mom, &w).unwrap();
        let want: f64 = w.iter().map(|&x| x as f64).sum();
        assert!((s.total_weight() - want).abs() < 1e-3);
        assert_eq!(s.samples_seen, n as u64);
    }

    #[test]
    fn cold_particles_in_first_bin() {
        let mut s = EnergySpectrum::new(None).unwrap();
        s.consume(&[0.0; 30], &[1.0; 10]).unwrap();
        assert_eq!(s.spectrum()[0], 10.0);
        assert_eq!(s.total_weight(), 10.0);
    }

    #[test]
    fn artifact_matches_fallback() {
        let dir = crate::runtime::Runtime::default_dir();
        if !dir.join("meta.json").exists() {
            return;
        }
        let rt = crate::runtime::Runtime::load(dir).unwrap();
        let mut rng = Rng::new(5);
        let n = 2000;
        let mom: Vec<f32> =
            (0..n * 3).map(|_| rng.normal() as f32 * 1.5).collect();
        let w: Vec<f32> = (0..n).map(|_| 0.5 + rng.f32()).collect();
        let mut a = EnergySpectrum::new(Some(&rt)).unwrap();
        a.consume(&mom, &w).unwrap();
        let mut b = EnergySpectrum::new(None).unwrap();
        b.consume(&mom, &w).unwrap();
        for (i, (x, y)) in
            a.spectrum().iter().zip(b.spectrum()).enumerate()
        {
            assert!((x - y).abs() < 1e-2 * y.abs().max(1.0),
                    "bin {i}: {x} vs {y}");
        }
    }

    #[test]
    fn merge_adds() {
        let mut a = EnergySpectrum::new(None).unwrap();
        a.consume(&[0.0; 3], &[2.0]).unwrap();
        let mut b = EnergySpectrum::new(None).unwrap();
        b.consume(&[0.0; 3], &[3.0]).unwrap();
        a.merge(&b);
        assert_eq!(a.spectrum()[0], 5.0);
        assert_eq!(a.samples_seen, 2);
    }
}
