//! Analysis consumers (S13): the GAPD stand-in and the binning stage.
//!
//! * [`saxs`] — the paper's §4.2 consumer: kinematical small-angle X-ray
//!   scattering over the particle stream, computed by the `saxs`
//!   artifact (L1 Pallas kernel on the MXU-shaped formulation), with a
//!   pure-rust oracle fallback.
//! * [`binning`] — the "filter and bin" stage of Fig. 2: a weighted
//!   kinetic-energy spectrum via the `binning` artifact.
//! * [`lint`] — `pallas-lint`, the static-analysis gate over the
//!   crate's own sources (panic-freedom zones, lock discipline,
//!   engine-contract conformance, format-fingerprint hygiene).

pub mod binning;
pub mod lint;
pub mod saxs;

pub use binning::EnergySpectrum;
pub use saxs::SaxsAnalyzer;
