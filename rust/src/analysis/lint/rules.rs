//! The lint rule implementations.
//!
//! Every rule is a pure function over a [`SourceFile`]: it pattern-
//! matches the token stream (comments and literal contents are already
//! gone — the lexer drops them) and pushes [`Finding`]s. Tokens inside
//! `#[cfg(test)]` / `#[cfg(debug_assertions)]` regions are exempt.
//!
//! Hardened-zone rules (`panic-site`, `index-literal`, `narrow-cast`,
//! `lock-across-blocking`, `nested-lock`) only run when
//! `SourceFile::hardened` is set; the rest run crate-wide.

use super::lexer::Token;
use super::{Finding, SourceFile};

/// Macros that abort the thread.
const PANIC_MACROS: &[&str] =
    &["panic", "todo", "unimplemented", "unreachable"];

/// Cast targets narrower than the wire's native u64/i64 — silent
/// truncation hazards in decode paths.
const NARROW_INTS: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32"];

/// Calls that can block indefinitely (channel / socket / thread).
/// Holding a lock across one of these stalls every other lock user.
const BLOCKING_CALLS: &[&str] = &[
    "send",
    "recv",
    "recv_timeout",
    "write_all",
    "read_exact",
    "read_to_end",
    "flush",
    "accept",
    "accept_timeout",
    "dial",
    "connect",
    "join",
    "sleep",
];

/// Run every rule over `sf`.
pub fn check_all(sf: &SourceFile, out: &mut Vec<Finding>) {
    panic_freedom(sf, out);
    lock_unwrap(sf, out);
    guard_discipline(sf, out);
    engine_override(sf, out);
    performgets_discipline(sf, out);
    allow_escape(sf, out);
}

fn tok<'a>(sf: &'a SourceFile, i: usize) -> Option<&'a Token> {
    sf.tokens.get(i)
}

fn is_punct_at(sf: &SourceFile, i: usize, c: char) -> bool {
    tok(sf, i).map(|t| t.is_punct(c)).unwrap_or(false)
}

fn is_ident_at(sf: &SourceFile, i: usize, s: &str) -> bool {
    tok(sf, i).map(|t| t.is_ident(s)).unwrap_or(false)
}

/// `panic-site`, `index-literal`, `narrow-cast` — hardened zones only.
fn panic_freedom(sf: &SourceFile, out: &mut Vec<Finding>) {
    if !sf.hardened {
        return;
    }
    let t = &sf.tokens;
    for i in 0..t.len() {
        if sf.exempt[i] {
            continue;
        }
        // `.unwrap(` / `.expect(`
        if let Some(name) = t[i].ident() {
            if (name == "unwrap" || name == "expect")
                && i > 0
                && t[i - 1].is_punct('.')
                && is_punct_at(sf, i + 1, '(')
            {
                out.push(Finding::new(
                    "panic-site",
                    &sf.path,
                    t[i].line,
                    format!(
                        "`.{}()` in a hardened zone — return a typed \
                         error (BpError / PoisonedLock / anyhow) instead",
                        name
                    ),
                ));
            }
            // `panic!(` / `todo!(` / `unimplemented!(` / `unreachable!(`
            if PANIC_MACROS.contains(&name)
                && is_punct_at(sf, i + 1, '!')
                && (is_punct_at(sf, i + 2, '(')
                    || is_punct_at(sf, i + 2, '[')
                    || is_punct_at(sf, i + 2, '{'))
            {
                out.push(Finding::new(
                    "panic-site",
                    &sf.path,
                    t[i].line,
                    format!(
                        "`{}!` in a hardened zone — a corrupt peer or \
                         file must surface an error, not tear the \
                         process down",
                        name
                    ),
                ));
            }
            // `as u8` etc.
            if name == "as" {
                if let Some(ty) =
                    tok(sf, i + 1).and_then(|n| n.ident())
                {
                    if NARROW_INTS.contains(&ty) {
                        out.push(Finding::new(
                            "narrow-cast",
                            &sf.path,
                            t[i].line,
                            format!(
                                "narrowing `as {}` in a hardened zone \
                                 — use `try_from` and surface the \
                                 overflow",
                                ty
                            ),
                        ));
                    }
                }
            }
        }
        // `expr[0]` — integer-literal indexing of a value (previous
        // token is an identifier, `)`, `]`, or `?`). Array type/len
        // syntax (`[0u8; 8]`) and attributes (`#[...]`) don't match.
        // Limitation: variable-index expressions (`buf[i]`) are out of
        // scope — they are usually range-checked by construction and
        // flagging them all would drown the signal.
        if t[i].is_punct('[')
            && i > 0
            && (t[i - 1].ident().is_some()
                || t[i - 1].is_punct(')')
                || t[i - 1].is_punct(']')
                || t[i - 1].is_punct('?'))
        {
            let lit = tok(sf, i + 1)
                .and_then(|n| n.num())
                .map(|n| !n.contains('.'))
                .unwrap_or(false);
            if lit && is_punct_at(sf, i + 2, ']') {
                out.push(Finding::new(
                    "index-literal",
                    &sf.path,
                    t[i].line,
                    "literal slice index in a hardened zone — panics \
                     on short input; use `get(..)`/`first()` and \
                     surface the error"
                        .to_string(),
                ));
            }
        }
    }
}

/// `lock-unwrap` — crate-wide: `.lock().unwrap()` / `.lock().expect(`
/// turns a poisoned mutex into a second panic.
fn lock_unwrap(sf: &SourceFile, out: &mut Vec<Finding>) {
    let t = &sf.tokens;
    for i in 0..t.len() {
        if sf.exempt[i] {
            continue;
        }
        if t[i].is_punct('.')
            && is_ident_at(sf, i + 1, "lock")
            && is_punct_at(sf, i + 2, '(')
            && is_punct_at(sf, i + 3, ')')
            && is_punct_at(sf, i + 4, '.')
            && (is_ident_at(sf, i + 5, "unwrap")
                || is_ident_at(sf, i + 5, "expect"))
            && is_punct_at(sf, i + 6, '(')
        {
            out.push(Finding::new(
                "lock-unwrap",
                &sf.path,
                t[i + 5].line,
                "`.lock().unwrap()` swallows poison into a panic — \
                 use util::sync::lock_or_poisoned"
                    .to_string(),
            ));
        }
    }
}

/// A mutex guard bound by `let` and still in scope.
struct LiveGuard {
    name: String,
    /// Normalized mutex expression (`self.shared`,
    /// `INPROC_REGISTRY`, ...) for nested-acquisition comparison.
    expr: String,
    /// Brace depth at creation: a `}` below this kills the guard.
    depth: usize,
}

/// Normalize a run of expression tokens to `ident.ident...` (drops
/// `&`, `mut`, `*`, `::`).
pub(super) fn expr_string(toks: &[&Token]) -> String {
    toks.iter()
        .filter_map(|t| t.ident())
        .filter(|s| *s != "mut")
        .collect::<Vec<_>>()
        .join(".")
}

/// Walk back from the `.` of `.lock(` over the receiver chain
/// (`self.shared.lock()` → start index of `self`, "self.shared").
pub(super) fn lock_receiver(t: &[Token], dot: usize) -> (usize, String) {
    let mut k = dot;
    loop {
        if k == 0 {
            break;
        }
        if t[k - 1].ident().is_some() {
            k -= 1;
            if k > 0
                && (t[k - 1].is_punct('.') || t[k - 1].is_punct(':'))
            {
                while k > 0
                    && (t[k - 1].is_punct('.')
                        || t[k - 1].is_punct(':'))
                {
                    k -= 1;
                }
                continue;
            }
        }
        break;
    }
    let parts: Vec<&Token> = t[k..dot].iter().collect();
    (k, expr_string(&parts))
}

/// First argument of `lock_or_poisoned(...)` as a normalized
/// expression; `open` is the index of the `(`.
pub(super) fn first_arg_expr(t: &[Token], open: usize) -> String {
    let mut depth = 0usize;
    let mut arg: Vec<&Token> = Vec::new();
    for token in t.iter().skip(open) {
        if token.is_punct('(') {
            depth += 1;
            if depth == 1 {
                continue;
            }
        } else if token.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if token.is_punct(',') && depth == 1 {
            break;
        }
        if depth >= 1 {
            arg.push(token);
        }
    }
    expr_string(&arg)
}

/// Is the statement ending at `rhs_start` of the form
/// `let [mut] NAME = <rhs>`? Returns the bound name.
fn binding_name(t: &[Token], rhs_start: usize) -> Option<String> {
    if rhs_start < 2 || !t[rhs_start - 1].is_punct('=') {
        return None;
    }
    let mut j = rhs_start - 2;
    let name = t[j].ident()?.to_string();
    if name == "mut" {
        return None;
    }
    if j >= 1 && t[j - 1].is_ident("mut") {
        j -= 1;
    }
    if j >= 1 && t[j - 1].is_ident("let") {
        return Some(name);
    }
    None
}

/// `lock-across-blocking` + `nested-lock` — hardened zones only.
///
/// Tracks `let`-bound guards from `lock_or_poisoned(...)` or
/// `.lock(...)`, scoped by braces and killed by `drop(name)`. While a
/// guard is live, a blocking call is a finding unless its receiver is
/// the *sole* live guard (the lock-the-sender serializer idiom), and
/// acquiring the same mutex expression again is a finding. Pattern-
/// and match-bound guards are not tracked (conservative: fewer false
/// positives).
fn guard_discipline(sf: &SourceFile, out: &mut Vec<Finding>) {
    if !sf.hardened {
        return;
    }
    let t = &sf.tokens;
    let mut guards: Vec<LiveGuard> = Vec::new();
    let mut depth = 0usize;
    for i in 0..t.len() {
        // Brace depth must track exempt regions too, or scopes drift.
        if t[i].is_punct('{') {
            depth += 1;
            continue;
        }
        if t[i].is_punct('}') {
            depth = depth.saturating_sub(1);
            guards.retain(|g| g.depth <= depth);
            continue;
        }
        if sf.exempt[i] {
            continue;
        }
        // `drop(name)` releases early.
        if t[i].is_ident("drop")
            && is_punct_at(sf, i + 1, '(')
            && is_punct_at(sf, i + 3, ')')
        {
            if let Some(name) = tok(sf, i + 2).and_then(|t| t.ident())
            {
                guards.retain(|g| g.name != name);
            }
        }
        // Acquisition via helper: `lock_or_poisoned(&m, ...)`.
        let acq = if t[i].is_ident("lock_or_poisoned")
            && is_punct_at(sf, i + 1, '(')
        {
            Some((i, first_arg_expr(t, i + 1)))
        } else if t[i].is_punct('.')
            && is_ident_at(sf, i + 1, "lock")
            && is_punct_at(sf, i + 2, '(')
        {
            // Acquisition via `.lock(`.
            let (start, expr) = lock_receiver(t, i);
            Some((start, expr))
        } else {
            None
        };
        if let Some((start, expr)) = acq {
            if !expr.is_empty() {
                if let Some(g) =
                    guards.iter().find(|g| g.expr == expr)
                {
                    out.push(Finding::new(
                        "nested-lock",
                        &sf.path,
                        t[i].line,
                        format!(
                            "`{}` is already locked here (guard \
                             `{}`) — re-acquiring self-deadlocks",
                            expr, g.name
                        ),
                    ));
                }
            }
            if let Some(name) = binding_name(t, start) {
                guards.push(LiveGuard { name, expr, depth });
            }
            continue;
        }
        // Blocking call with a guard live: `.send(` / `::connect(` ...
        if guards.is_empty() {
            continue;
        }
        if let Some(name) = t[i].ident() {
            if BLOCKING_CALLS.contains(&name)
                && is_punct_at(sf, i + 1, '(')
                && i > 0
                && (t[i - 1].is_punct('.') || t[i - 1].is_punct(':'))
            {
                // Receiver-is-the-sole-guard: `tx.send(..)` where `tx`
                // guards only the sender is the sanctioned serializer
                // idiom — but only while no OTHER lock is held, or the
                // send still stalls every user of that other lock.
                let recv_is_sole_guard = i >= 2
                    && t[i - 1].is_punct('.')
                    && t[i - 2]
                        .ident()
                        .map(|r| guards.iter().all(|g| g.name == r))
                        .unwrap_or(false);
                if !recv_is_sole_guard {
                    let held: Vec<&str> = guards
                        .iter()
                        .map(|g| g.name.as_str())
                        .collect();
                    out.push(Finding::new(
                        "lock-across-blocking",
                        &sf.path,
                        t[i].line,
                        format!(
                            "blocking `{}` while holding lock \
                             guard(s) {} — release first or waive \
                             with the reason the lock must span it",
                            name,
                            held.join(", ")
                        ),
                    ));
                }
            }
        }
    }
}

/// Find the body `{..}` of an item starting at token `from`: the first
/// `{` unless a `;` ends a braceless declaration first. Returns the
/// token range inside the braces.
pub(super) fn body_range(
    t: &[Token],
    from: usize,
) -> Option<(usize, usize)> {
    let mut j = from;
    while j < t.len() {
        if t[j].is_punct(';') {
            return None;
        }
        if t[j].is_punct('{') {
            break;
        }
        j += 1;
    }
    if j >= t.len() {
        return None;
    }
    let start = j + 1;
    let mut depth = 0usize;
    while j < t.len() {
        if t[j].is_punct('{') {
            depth += 1;
        } else if t[j].is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return Some((start, j));
            }
        }
        j += 1;
    }
    Some((start, t.len()))
}

/// `engine-override` — crate-wide: `impl Engine for X` must not
/// redefine the eager `put`/`get` trait defaults; backends express
/// semantics through `put_deferred`/`get_deferred` + `perform_*`, and
/// the defaults guarantee eager calls stay equivalent to
/// deferred-then-perform everywhere.
fn engine_override(sf: &SourceFile, out: &mut Vec<Finding>) {
    let t = &sf.tokens;
    let mut i = 0usize;
    while i < t.len() {
        if !t[i].is_ident("impl") || sf.exempt[i] {
            i += 1;
            continue;
        }
        // Header runs to the first `{`; the implemented trait is the
        // ident right before `for`.
        let mut j = i + 1;
        let mut trait_is_engine = false;
        while j < t.len() && !t[j].is_punct('{') {
            if t[j].is_ident("for")
                && j > 0
                && t[j - 1].is_ident("Engine")
            {
                trait_is_engine = true;
            }
            if t[j].is_punct(';') {
                break;
            }
            j += 1;
        }
        if !trait_is_engine {
            i = j.max(i + 1);
            continue;
        }
        let Some((start, end)) = body_range(t, j) else {
            i = j.max(i + 1);
            continue;
        };
        for k in start..end {
            if t[k].is_ident("fn")
                && k + 2 < t.len()
                && (t[k + 1].is_ident("put") || t[k + 1].is_ident("get"))
                && t[k + 2].is_punct('(')
            {
                out.push(Finding::new(
                    "engine-override",
                    &sf.path,
                    t[k + 1].line,
                    format!(
                        "`impl Engine` overrides the eager `{}` trait \
                         default — express backend behavior through \
                         the deferred queue instead",
                        t[k + 1].ident().unwrap_or("?"),
                    ),
                ));
            }
        }
        i = end + 1;
    }
}

/// `performgets-discipline` — crate-wide: a `perform_gets` body that
/// drains the deferred queue must reach `fail_batch`/`poison` so
/// outstanding `GetHandle`s never dangle on error. Delegating wrappers
/// and write-mode `bail!` stubs (no `drain_pending`) pass.
fn performgets_discipline(sf: &SourceFile, out: &mut Vec<Finding>) {
    let t = &sf.tokens;
    for i in 0..t.len() {
        if sf.exempt[i]
            || !t[i].is_ident("fn")
            || !is_ident_at(sf, i + 1, "perform_gets")
        {
            continue;
        }
        let Some((start, end)) = body_range(t, i + 2) else {
            continue;
        };
        let body = &t[start..end];
        let has = |s: &str| body.iter().any(|t| t.is_ident(s));
        if has("drain_pending") && !has("fail_batch") && !has("poison")
        {
            out.push(Finding::new(
                "performgets-discipline",
                &sf.path,
                t[i + 1].line,
                "`perform_gets` drains the deferred queue but no \
                 error arm reaches `fail_batch`/`poison` — failed \
                 batches must poison their handles"
                    .to_string(),
            ));
        }
    }
}

/// `allow-escape` — crate-wide: `#[allow(...)]` / `#![allow(...)]`
/// outside test code silences the compiler with no recorded reason;
/// fix the code or use a budgeted `lint:allow` waiver.
fn allow_escape(sf: &SourceFile, out: &mut Vec<Finding>) {
    let t = &sf.tokens;
    for i in 0..t.len() {
        if sf.exempt[i] || !t[i].is_punct('#') {
            continue;
        }
        let mut j = i + 1;
        if is_punct_at(sf, j, '!') {
            j += 1;
        }
        if is_punct_at(sf, j, '[')
            && is_ident_at(sf, j + 1, "allow")
            && is_punct_at(sf, j + 2, '(')
        {
            out.push(Finding::new(
                "allow-escape",
                &sf.path,
                t[j + 1].line,
                "`#[allow(..)]` outside test code — delete the dead \
                 code or justify it where it stands"
                    .to_string(),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::analysis::lint::lint_source;

    const HARD: &str = "rust/src/adios/wire.rs";
    const SOFT: &str = "rust/src/util/stats.rs";

    fn rules(path: &str, src: &str) -> Vec<&'static str> {
        lint_source(path, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unwrap_expect_flagged_in_hardened_only() {
        let src = "fn f(x: Option<u8>) { x.unwrap(); x.expect(\"y\"); }";
        assert_eq!(rules(HARD, src), ["panic-site", "panic-site"]);
        assert_eq!(rules(SOFT, src), Vec::<&str>::new());
        // unwrap_or / unwrap_or_else / fn defs named unwrap don't fire.
        let ok = "fn f(x: Option<u8>) { x.unwrap_or(0); \
                  x.unwrap_or_else(|| 0); }\nfn unwrap() {}";
        assert_eq!(rules(HARD, ok), Vec::<&str>::new());
    }

    #[test]
    fn panic_macros_flagged() {
        let src = "fn f() { panic!(\"x\"); todo!(); unreachable!(); \
                   unimplemented!() }";
        assert_eq!(rules(HARD, src).len(), 4);
        // `std::panic::catch_unwind` is not a panic site.
        assert_eq!(
            rules(HARD, "fn f() { std::panic::catch_unwind(|| 0); }"),
            Vec::<&str>::new()
        );
        // Test code is exempt.
        let test_src = "#[cfg(test)]\nmod t { fn f() { panic!(\"x\") } }";
        assert_eq!(rules(HARD, test_src), Vec::<&str>::new());
    }

    #[test]
    fn literal_index_flagged_variable_index_not() {
        assert_eq!(
            rules(HARD, "fn f(b: &[u8]) -> u8 { b[0] }"),
            ["index-literal"]
        );
        assert_eq!(
            rules(HARD, "fn f(&mut self) -> u8 { self.take(1)?[0] }"),
            ["index-literal"]
        );
        // Variable index, array literals, attributes: out of scope.
        let ok = "#[derive(Debug)]\nfn f(b: &[u8], i: usize) -> u8 { \
                  let a = [0u8; 8]; b[i] + a[i] }";
        assert_eq!(rules(HARD, ok), Vec::<&str>::new());
    }

    #[test]
    fn narrowing_casts_flagged() {
        assert_eq!(
            rules(HARD, "fn f(x: u64) -> u32 { x as u32 }"),
            ["narrow-cast"]
        );
        // Widening / same-width is fine.
        let ok = "fn f(x: u32, l: usize) { let a = x as u64; \
                  let b = l as i64; let c = x as usize; }";
        assert_eq!(rules(HARD, ok), Vec::<&str>::new());
    }

    #[test]
    fn lock_unwrap_is_crate_wide() {
        let src = "fn f(&self) { self.m.lock().unwrap().push(1); }";
        assert_eq!(rules(SOFT, src), ["lock-unwrap"]);
        // In a hardened file the panic-site rule fires too.
        let mut r = rules(HARD, src);
        r.sort();
        assert_eq!(r, ["lock-unwrap", "panic-site"]);
        assert_eq!(
            rules(SOFT, "fn f(&self) { self.m.lock().expect(\"m\"); }"),
            ["lock-unwrap"]
        );
    }

    #[test]
    fn blocking_call_under_guard_flagged() {
        let src = "fn f(&self) -> Result<()> {\n\
                   let mut sh = lock_or_poisoned(&self.shared, \"s\")?;\n\
                   sh.steps += 1;\n\
                   self.tx.send(1)?;\n\
                   Ok(())\n}";
        assert_eq!(rules(HARD, src), ["lock-across-blocking"]);
        // Dropping the guard first is clean.
        let ok = "fn f(&self) -> Result<()> {\n\
                  let mut sh = lock_or_poisoned(&self.shared, \"s\")?;\n\
                  sh.steps += 1;\n\
                  drop(sh);\n\
                  self.tx.send(1)?;\n\
                  Ok(())\n}";
        assert_eq!(rules(HARD, ok), Vec::<&str>::new());
        // Scope exit releases too.
        let scoped = "fn f(&self) -> Result<()> {\n\
                      { let sh = lock_or_poisoned(&self.s, \"s\")?; }\n\
                      self.tx.send(1)?;\nOk(())\n}";
        assert_eq!(rules(HARD, scoped), Vec::<&str>::new());
    }

    #[test]
    fn serializer_idiom_is_exempt() {
        // The guard IS the sender: lock guards only the send.
        let src = "fn f(&self) -> Result<()> {\n\
                   let mut tx = lock_or_poisoned(&self.tx, \"tx\")?;\n\
                   tx.send(1)?;\nOk(())\n}";
        assert_eq!(rules(HARD, src), Vec::<&str>::new());
        // ... but not while a SECOND lock is held: the send then
        // stalls every user of the other lock too.
        let two = "fn f(&self) -> Result<()> {\n\
                   let mut sh = lock_or_poisoned(&self.shared, \"s\")?;\n\
                   let mut tx = lock_or_poisoned(&self.tx, \"tx\")?;\n\
                   tx.send(1)?;\nOk(())\n}";
        assert_eq!(rules(HARD, two), ["lock-across-blocking"]);
    }

    #[test]
    fn plain_lock_guards_are_tracked_too() {
        let src = "fn f(&self) -> Result<()> {\n\
                   let g = self.shared.lock().map_err(|_| x)?;\n\
                   self.tx.send(1)?;\nOk(())\n}";
        assert_eq!(rules(HARD, src), ["lock-across-blocking"]);
    }

    #[test]
    fn nested_same_mutex_flagged() {
        let src = "fn f(&self) -> Result<()> {\n\
                   let a = lock_or_poisoned(&self.shared, \"a\")?;\n\
                   let b = lock_or_poisoned(&self.shared, \"b\")?;\n\
                   Ok(())\n}";
        assert_eq!(rules(HARD, src), ["nested-lock"]);
        // Different mutexes are fine.
        let ok = "fn f(&self) -> Result<()> {\n\
                  let a = lock_or_poisoned(&self.shared, \"a\")?;\n\
                  let b = lock_or_poisoned(&self.other, \"b\")?;\n\
                  Ok(())\n}";
        assert_eq!(rules(HARD, ok), Vec::<&str>::new());
    }

    #[test]
    fn engine_override_flagged() {
        let src = "impl Engine for Foo {\n\
                   fn put(&mut self, h: &VarHandle) -> Result<()> { \
                   Ok(()) }\n}";
        assert_eq!(rules(SOFT, src), ["engine-override"]);
        // Deferred methods, other traits, and the trait's own defaults
        // are fine.
        let ok = "impl Engine for Foo { fn put_deferred(&mut self) {} }\n\
                  impl Display for Engine2 { fn put(&self) {} }\n\
                  pub trait Engine: Send { fn put(&mut self) {} }";
        assert_eq!(rules(SOFT, ok), Vec::<&str>::new());
    }

    #[test]
    fn performgets_must_poison_when_draining() {
        let bad = "impl Engine for F {\nfn perform_gets(&mut self) -> \
                   Result<()> { let p = self.gets.drain_pending(); \
                   Ok(()) }\n}";
        assert_eq!(rules(SOFT, bad), ["performgets-discipline"]);
        let good = "impl Engine for F {\nfn perform_gets(&mut self) -> \
                    Result<()> { let p = self.gets.drain_pending(); \
                    if bad { self.gets.fail_batch(p, e); }\nOk(()) }\n}";
        assert_eq!(rules(SOFT, good), Vec::<&str>::new());
        // Delegating wrappers and bail!-stubs have no drain.
        let stub = "fn perform_gets(&mut self) -> Result<()> { \
                    self.inner.perform_gets() }";
        assert_eq!(rules(SOFT, stub), Vec::<&str>::new());
        // Trait declarations (no body) are skipped.
        let decl = "pub trait Engine: Send { fn perform_gets(&mut \
                    self) -> Result<()>; }";
        assert_eq!(rules(SOFT, decl), Vec::<&str>::new());
    }

    #[test]
    fn allow_attributes_flagged_outside_tests() {
        assert_eq!(
            rules(SOFT, "#[allow(dead_code)]\nfn f() {}"),
            ["allow-escape"]
        );
        assert_eq!(
            rules(SOFT, "#![allow(unused_imports)]\nuse x;"),
            ["allow-escape"]
        );
        let ok = "#[cfg(test)]\nmod t {\n#![allow(dead_code)]\n}\n\
                  #[allow_other(x)]\nfn f() {}";
        assert_eq!(rules(SOFT, ok), Vec::<&str>::new());
    }
}
