//! Format-fingerprint hygiene (the `format-fingerprint` rule).
//!
//! The on-disk BP layout and the SST wire protocol are contracts with
//! every peer and every previously-written file. Silently editing
//! `StepMeta::encode`, `encode_msg`, or `BpWriter::end_step` — or the
//! `Msg` tag map — without bumping the corresponding version string
//! (`MAGIC` in `bp.rs`, `WIRE_FORMAT` in `wire.rs`) produces readers
//! and writers that disagree while claiming compatibility.
//!
//! This module extracts a *structural* fingerprint of those layouts
//! (the ordered sequence of serializer calls in each encode body, plus
//! the tag map and version strings) and compares it against the
//! committed manifest `tools/lint/format.fingerprint.json`. A diff is a
//! finding; `pallas-lint --bless` regenerates the manifest but refuses
//! when a layout changed while its version string did not.
//!
//! The fingerprint is deliberately token-structural rather than a
//! source hash: formatting, comments, and variable renames don't
//! disturb it — only the actual serialization sequence does.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::lexer::{self, Token};
use super::{rules, Finding};
use crate::util::json::{self, Json};

/// Call-position identifiers that constitute a serialized layout.
/// Only calls to these (in order) enter the fingerprint; control flow
/// and arithmetic around them do not.
const OP_VOCAB: &[&str] = &[
    "put_u64",
    "put_str",
    "put_vec_u64",
    "put_chunk",
    "push",
    "extend_from_slice",
    "encode",
    "write_all",
];

/// The structural fingerprint of the two format-bearing modules.
#[derive(Debug, PartialEq)]
pub struct Fingerprint {
    /// `const MAGIC` in `bp.rs` (e.g. `OPMDBP03`).
    pub bp_magic: String,
    /// `const WIRE_FORMAT` in `wire.rs`.
    pub wire_version: String,
    /// `Msg` variant → tag byte, from `Msg::tag`.
    pub msg_tags: BTreeMap<String, u64>,
    /// Layout name → ordered serializer-call sequence.
    pub layouts: BTreeMap<String, Vec<String>>,
}

/// The layouts recorded per module (manifest key → owner/function).
const WIRE_LAYOUTS: &[(&str, Option<&str>, &str)] = &[
    ("wire.rs::StepMeta::encode", Some("StepMeta"), "encode"),
    ("wire.rs::encode_msg", None, "encode_msg"),
];
const BP_LAYOUTS: &[(&str, Option<&str>, &str)] =
    &[("bp.rs::BpWriter::end_step", Some("BpWriter"), "end_step")];

/// Value of `const NAME: .. = ["b"]"VALUE"`, by raw text scan — the
/// lexer drops string contents, so the source text is the authority.
fn const_str(src: &str, name: &str) -> Option<String> {
    let at = src.find(&format!("const {name}"))?;
    let rest = &src[at..];
    let q = rest.find('"')?;
    let rest = &rest[q + 1..];
    Some(rest[..rest.find('"')?].to_string())
}

fn match_brace(t: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    let mut k = open;
    while k < t.len() {
        if t[k].is_punct('{') {
            depth += 1;
        } else if t[k].is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
        k += 1;
    }
    t.len().saturating_sub(1)
}

/// Find `fn name`'s body tokens within `t[start..end]`.
fn fn_body<'a>(
    t: &'a [Token],
    start: usize,
    end: usize,
    name: &str,
) -> Option<&'a [Token]> {
    let mut i = start;
    while i + 1 < end {
        if t[i].is_ident("fn") && t[i + 1].is_ident(name) {
            let (b, e) = rules::body_range(t, i + 2)?;
            return Some(&t[b..e]);
        }
        i += 1;
    }
    None
}

/// Find `fn name`'s body, optionally qualified by the impl self type:
/// `owner = Some("BpWriter")` matches both `impl BpWriter` and
/// `impl Engine for BpWriter` (the owner must be the self type — after
/// `for` when a trait is implemented).
fn body_of<'a>(
    t: &'a [Token],
    owner: Option<&str>,
    name: &str,
) -> Option<&'a [Token]> {
    let Some(owner) = owner else {
        return fn_body(t, 0, t.len(), name);
    };
    let mut i = 0usize;
    while i < t.len() {
        if !t[i].is_ident("impl") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        let mut for_at: Option<usize> = None;
        let mut owner_at: Option<usize> = None;
        while j < t.len()
            && !t[j].is_punct('{')
            && !t[j].is_punct(';')
        {
            if t[j].is_ident("for") {
                for_at.get_or_insert(j);
            }
            if t[j].is_ident(owner) {
                owner_at = Some(j);
            }
            j += 1;
        }
        if j >= t.len() || !t[j].is_punct('{') {
            i = j.max(i + 1);
            continue;
        }
        let end = match_brace(t, j);
        let is_owner = match (owner_at, for_at) {
            (Some(o), Some(f)) => o > f,
            (Some(_), None) => true,
            _ => false,
        };
        if is_owner {
            if let Some(b) = fn_body(t, j + 1, end, name) {
                return Some(b);
            }
        }
        i = end + 1;
    }
    None
}

/// Ordered serializer calls (vocabulary-filtered, call position only).
fn ops(body: &[Token]) -> Vec<String> {
    let mut out = Vec::new();
    for i in 0..body.len() {
        if let Some(id) = body[i].ident() {
            if OP_VOCAB.contains(&id)
                && body
                    .get(i + 1)
                    .map(|n| n.is_punct('('))
                    .unwrap_or(false)
            {
                out.push(id.to_string());
            }
        }
    }
    out
}

/// `Msg` variant → tag, from the match arms of `fn tag`
/// (`Msg::Hello { .. } => 1`).
fn msg_tags(t: &[Token]) -> BTreeMap<String, u64> {
    let mut out = BTreeMap::new();
    let Some(body) = body_of(t, None, "tag") else {
        return out;
    };
    let mut last: Option<String> = None;
    let mut i = 0usize;
    while i < body.len() {
        if body[i].is_ident("Msg")
            && body.get(i + 1).map(|x| x.is_punct(':')).unwrap_or(false)
            && body.get(i + 2).map(|x| x.is_punct(':')).unwrap_or(false)
        {
            if let Some(v) = body.get(i + 3).and_then(|x| x.ident()) {
                last = Some(v.to_string());
                i += 4;
                continue;
            }
        }
        if body[i].is_punct('=')
            && body.get(i + 1).map(|x| x.is_punct('>')).unwrap_or(false)
        {
            if let Some(n) = body.get(i + 2).and_then(|x| x.num()) {
                if let (Some(name), Ok(tag)) = (
                    last.take(),
                    n.replace('_', "").parse::<u64>(),
                ) {
                    out.insert(name, tag);
                }
            }
            i += 2;
            continue;
        }
        i += 1;
    }
    out
}

/// Extract the live fingerprint from the sources under `root`.
pub fn extract(root: &Path) -> Result<Fingerprint> {
    let wire_path = root.join("rust/src/adios/wire.rs");
    let bp_path = root.join("rust/src/adios/bp.rs");
    let wire_src = std::fs::read_to_string(&wire_path)
        .with_context(|| format!("reading {}", wire_path.display()))?;
    let bp_src = std::fs::read_to_string(&bp_path)
        .with_context(|| format!("reading {}", bp_path.display()))?;
    let wire = lexer::lex(&wire_src).tokens;
    let bp = lexer::lex(&bp_src).tokens;

    let bp_magic = const_str(&bp_src, "MAGIC")
        .ok_or_else(|| anyhow!("bp.rs: `const MAGIC` not found"))?;
    let wire_version = const_str(&wire_src, "WIRE_FORMAT").ok_or_else(
        || anyhow!("wire.rs: `const WIRE_FORMAT` not found"),
    )?;
    let tags = msg_tags(&wire);
    if tags.is_empty() {
        bail!("wire.rs: no Msg tags extracted from `fn tag`");
    }
    let mut layouts = BTreeMap::new();
    for (toks, specs) in
        [(&wire, WIRE_LAYOUTS), (&bp, BP_LAYOUTS)]
    {
        for (key, owner, name) in specs {
            let body = body_of(toks, *owner, name).ok_or_else(|| {
                anyhow!("fingerprint target `{}` not found", key)
            })?;
            layouts.insert((*key).to_string(), ops(body));
        }
    }
    Ok(Fingerprint { bp_magic, wire_version, msg_tags: tags, layouts })
}

impl Fingerprint {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("bp_magic".into(), Json::Str(self.bp_magic.clone()));
        o.insert(
            "wire_version".into(),
            Json::Str(self.wire_version.clone()),
        );
        o.insert(
            "msg_tags".into(),
            Json::Obj(
                self.msg_tags
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                    .collect(),
            ),
        );
        o.insert(
            "layouts".into(),
            Json::Obj(
                self.layouts
                    .iter()
                    .map(|(k, ops)| {
                        (
                            k.clone(),
                            Json::Arr(
                                ops.iter()
                                    .map(|s| Json::Str(s.clone()))
                                    .collect(),
                            ),
                        )
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Fingerprint> {
        let field = |k: &str| {
            j.get(k).ok_or_else(|| anyhow!("manifest missing `{k}`"))
        };
        let s = |k: &str| -> Result<String> {
            Ok(field(k)?
                .as_str()
                .ok_or_else(|| anyhow!("manifest `{k}` not a string"))?
                .to_string())
        };
        let mut msg_tags = BTreeMap::new();
        for (k, v) in field("msg_tags")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest `msg_tags` not an object"))?
        {
            msg_tags.insert(
                k.clone(),
                v.as_u64().ok_or_else(|| {
                    anyhow!("manifest tag `{k}` not an integer")
                })?,
            );
        }
        let mut layouts = BTreeMap::new();
        for (k, v) in field("layouts")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest `layouts` not an object"))?
        {
            let ops = v
                .as_arr()
                .ok_or_else(|| anyhow!("layout `{k}` not an array"))?
                .iter()
                .map(|x| {
                    x.as_str().map(str::to_string).ok_or_else(|| {
                        anyhow!("layout `{k}` has a non-string op")
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            layouts.insert(k.clone(), ops);
        }
        Ok(Fingerprint {
            bp_magic: s("bp_magic")?,
            wire_version: s("wire_version")?,
            msg_tags,
            layouts,
        })
    }
}

fn diff_module(
    module_file: &str,
    version_name: &str,
    version_changed: bool,
    changed_keys: &[&str],
    out: &mut Vec<Finding>,
) {
    if changed_keys.is_empty() {
        if version_changed {
            out.push(Finding::new(
                "format-fingerprint",
                module_file,
                0,
                format!(
                    "`{version_name}` was bumped but the recorded \
                     manifest still holds the old value — run \
                     `pallas-lint --bless`"
                ),
            ));
        }
        return;
    }
    let what = changed_keys.join(", ");
    let msg = if version_changed {
        format!(
            "serialized layout changed ({what}) — run `pallas-lint \
             --bless` to record the new fingerprint"
        )
    } else {
        format!(
            "serialized layout changed ({what}) without bumping \
             `{version_name}` — old readers will misparse; bump the \
             version, then `pallas-lint --bless`"
        )
    };
    out.push(Finding::new("format-fingerprint", module_file, 0, msg));
}

/// Compare the live fingerprint against the manifest; mismatches are
/// `format-fingerprint` findings. IO/parse problems are hard errors.
pub fn check(
    root: &Path,
    manifest: &Path,
    out: &mut Vec<Finding>,
) -> Result<()> {
    let current = extract(root)?;
    let text = match std::fs::read_to_string(manifest) {
        Ok(t) => t,
        Err(_) => {
            out.push(Finding::new(
                "format-fingerprint",
                "tools/lint/format.fingerprint.json",
                0,
                "fingerprint manifest missing — run `pallas-lint \
                 --bless` and commit it"
                    .to_string(),
            ));
            return Ok(());
        }
    };
    let recorded = Fingerprint::from_json(
        &json::parse(&text)
            .map_err(|e| anyhow!("parsing fingerprint manifest: {e}"))?,
    )?;

    let changed = |keys: &[(&str, Option<&str>, &str)]| -> Vec<&str> {
        keys.iter()
            .map(|(k, _, _)| *k)
            .filter(|k| current.layouts.get(*k) != recorded.layouts.get(*k))
            .collect()
    };
    let mut wire_changed = changed(WIRE_LAYOUTS);
    if current.msg_tags != recorded.msg_tags {
        wire_changed.push("wire.rs::Msg tags");
    }
    diff_module(
        "rust/src/adios/wire.rs",
        "WIRE_FORMAT",
        current.wire_version != recorded.wire_version,
        &wire_changed,
        out,
    );
    diff_module(
        "rust/src/adios/bp.rs",
        "MAGIC",
        current.bp_magic != recorded.bp_magic,
        &changed(BP_LAYOUTS),
        out,
    );
    Ok(())
}

/// Regenerate the manifest — unless a layout changed while its version
/// string did not, which is exactly the mistake the rule exists to
/// catch.
pub fn bless(root: &Path, manifest: &Path) -> Result<String> {
    let current = extract(root)?;
    if let Ok(text) = std::fs::read_to_string(manifest) {
        let old = Fingerprint::from_json(
            &json::parse(&text).map_err(|e| {
                anyhow!("parsing existing manifest: {e}")
            })?,
        )?;
        let key_changed = |keys: &[(&str, Option<&str>, &str)]| {
            keys.iter().any(|(k, _, _)| {
                current.layouts.get(*k) != old.layouts.get(*k)
            })
        };
        if (key_changed(WIRE_LAYOUTS)
            || current.msg_tags != old.msg_tags)
            && current.wire_version == old.wire_version
        {
            bail!(
                "refusing to bless: the wire layout changed but \
                 WIRE_FORMAT is still {:?} — bump it first",
                current.wire_version
            );
        }
        if key_changed(BP_LAYOUTS) && current.bp_magic == old.bp_magic {
            bail!(
                "refusing to bless: the BP layout changed but MAGIC \
                 is still {:?} — bump it first",
                current.bp_magic
            );
        }
    }
    if let Some(dir) = manifest.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    let mut body = current.to_json().to_string_pretty();
    body.push('\n');
    std::fs::write(manifest, body)
        .with_context(|| format!("writing {}", manifest.display()))?;
    Ok(format!("fingerprint manifest written: {}", manifest.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const WIRE_FIXTURE: &str = r#"
pub const WIRE_FORMAT: &str = "TESTWIRE01";
impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::StepAnnounce(_) => 3,
            Msg::Bye => 9,
        }
    }
}
impl StepMeta {
    pub fn encode(&self, out: &mut Vec<u8>) {
        put_u64(out, self.step);
        for v in &self.vars {
            put_str(out, &v.name);
            v.meta.encode(out);
        }
    }
}
pub fn encode_msg(msg: &Msg) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(msg.tag());
    put_u64(&mut out, 0);
    out
}
"#;

    const BP_FIXTURE: &str = r#"
const MAGIC: &[u8; 8] = b"TESTBP01";
impl BpWriter {
    pub fn create() {}
}
impl Engine for BpWriter {
    fn end_step(&mut self) -> Result<()> {
        self.buf.extend_from_slice(MAGIC);
        self.file.write_all(&self.buf)?;
        Ok(())
    }
}
impl Engine for BpReader {
    fn end_step(&mut self) -> Result<()> {
        self.step += 1;
        Ok(())
    }
}
"#;

    fn fixture_root(tag: &str) -> std::path::PathBuf {
        let root = std::env::temp_dir().join(format!(
            "pallas-lint-fp-{}-{}",
            tag,
            std::process::id()
        ));
        let adios = root.join("rust/src/adios");
        std::fs::create_dir_all(&adios).unwrap();
        std::fs::write(adios.join("wire.rs"), WIRE_FIXTURE).unwrap();
        std::fs::write(adios.join("bp.rs"), BP_FIXTURE).unwrap();
        root
    }

    #[test]
    fn extracts_structural_fingerprint() {
        let root = fixture_root("extract");
        let fp = extract(&root).unwrap();
        assert_eq!(fp.bp_magic, "TESTBP01");
        assert_eq!(fp.wire_version, "TESTWIRE01");
        assert_eq!(fp.msg_tags.get("Hello"), Some(&1));
        assert_eq!(fp.msg_tags.get("StepAnnounce"), Some(&3));
        assert_eq!(fp.msg_tags.get("Bye"), Some(&9));
        assert_eq!(
            fp.layouts["wire.rs::StepMeta::encode"],
            vec!["put_u64", "put_str", "encode"]
        );
        assert_eq!(
            fp.layouts["wire.rs::encode_msg"],
            vec!["push", "put_u64"]
        );
        // BpWriter's end_step, not BpReader's.
        assert_eq!(
            fp.layouts["bp.rs::BpWriter::end_step"],
            vec!["extend_from_slice", "write_all"]
        );
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn manifest_round_trips() {
        let root = fixture_root("roundtrip");
        let fp = extract(&root).unwrap();
        let back = Fingerprint::from_json(
            &json::parse(&fp.to_json().to_string_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(back, fp);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn layout_drift_without_version_bump_is_caught() {
        let root = fixture_root("drift");
        let manifest = root.join("fingerprint.json");
        bless(&root, &manifest).unwrap();

        // Clean check after bless.
        let mut f = Vec::new();
        check(&root, &manifest, &mut f).unwrap();
        assert!(f.is_empty(), "{f:?}");

        // Reorder the BP layout without touching MAGIC.
        let bp = root.join("rust/src/adios/bp.rs");
        let src = std::fs::read_to_string(&bp)
            .unwrap()
            .replace(
                "self.buf.extend_from_slice(MAGIC);\n        \
                 self.file.write_all(&self.buf)?;",
                "self.file.write_all(&self.buf)?;\n        \
                 self.buf.extend_from_slice(MAGIC);",
            );
        std::fs::write(&bp, src).unwrap();

        let mut f = Vec::new();
        check(&root, &manifest, &mut f).unwrap();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "format-fingerprint");
        assert!(f[0].message.contains("MAGIC"), "{}", f[0].message);

        // And bless refuses to paper over it.
        let err = bless(&root, &manifest).unwrap_err().to_string();
        assert!(err.contains("refusing to bless"), "{err}");

        // Bumping MAGIC unblocks the bless.
        let src = std::fs::read_to_string(&bp)
            .unwrap()
            .replace("TESTBP01", "TESTBP02");
        std::fs::write(&bp, src).unwrap();
        bless(&root, &manifest).unwrap();
        let mut f = Vec::new();
        check(&root, &manifest, &mut f).unwrap();
        assert!(f.is_empty(), "{f:?}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn missing_manifest_is_a_finding_not_an_error() {
        let root = fixture_root("missing");
        let mut f = Vec::new();
        check(&root, &root.join("nope.json"), &mut f).unwrap();
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("--bless"));
        std::fs::remove_dir_all(&root).ok();
    }
}
