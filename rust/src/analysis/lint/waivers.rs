//! The waiver-budget ledger (`tools/lint/waivers.ledger`).
//!
//! Inline `lint:allow` directives keep a waiver next to the code it
//! excuses; the ledger keeps the *total* under version control so it
//! can only move by an explicit, reviewable edit. Each line is
//!
//! ```text
//! <rule> <budget>        # comment
//! ```
//!
//! and the check is an equality, not an upper bound: more waived
//! findings than budget fails (no silent growth), fewer also fails
//! (the ledger must shrink in the same commit that removes a waiver —
//! that is the shrink-only ratchet). A waived finding whose rule has
//! no ledger line fails too.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Finding;

/// Parse the ledger: rule → (budget, line number).
fn parse(
    path: &Path,
    text: &str,
) -> Result<BTreeMap<String, (usize, u32)>> {
    let mut out = BTreeMap::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(rule), Some(budget), None) =
            (parts.next(), parts.next(), parts.next())
        else {
            bail!(
                "{}:{}: expected `<rule> <budget>`, got {:?}",
                path.display(),
                idx + 1,
                raw
            );
        };
        if !super::RULES.contains(&rule) {
            bail!(
                "{}:{}: unknown rule {:?}",
                path.display(),
                idx + 1,
                rule
            );
        }
        let budget: usize = budget.parse().with_context(|| {
            format!(
                "{}:{}: budget {:?} is not a number",
                path.display(),
                idx + 1,
                budget
            )
        })?;
        if out.insert(rule.to_string(), (budget, idx as u32 + 1))
            .is_some()
        {
            bail!(
                "{}:{}: duplicate ledger entry for {:?}",
                path.display(),
                idx + 1,
                rule
            );
        }
    }
    Ok(out)
}

/// Enforce the ledger against the waived findings already collected in
/// `out`. A missing ledger file is an empty ledger (every waiver is
/// then over budget); a malformed one is a hard error.
pub fn check(ledger: &Path, out: &mut Vec<Finding>) -> Result<()> {
    let budgets = match std::fs::read_to_string(ledger) {
        Ok(text) => parse(ledger, &text)?,
        Err(_) => BTreeMap::new(),
    };
    let mut waived: BTreeMap<&'static str, usize> = BTreeMap::new();
    for f in out.iter().filter(|f| f.waived.is_some()) {
        *waived.entry(f.rule).or_insert(0) += 1;
    }
    let label = ledger.display().to_string();
    for (&rule, &n) in &waived {
        match budgets.get(rule) {
            None => out.push(Finding::new(
                "waiver-ledger",
                &label,
                0,
                format!(
                    "{n} waived `{rule}` finding(s) but the ledger has \
                     no `{rule}` line — waivers must be budgeted"
                ),
            )),
            Some(&(budget, line)) if n > budget => {
                out.push(Finding::new(
                    "waiver-ledger",
                    &label,
                    line,
                    format!(
                        "`{rule}` budget is {budget} but {n} findings \
                         are waived — fix the code instead of adding \
                         waivers"
                    ),
                ))
            }
            Some(&(budget, line)) if n < budget => {
                out.push(Finding::new(
                    "waiver-ledger",
                    &label,
                    line,
                    format!(
                        "`{rule}` budget is {budget} but only {n} \
                         finding(s) are waived — shrink the budget \
                         (the ledger is a ratchet)"
                    ),
                ))
            }
            Some(_) => {}
        }
    }
    for (rule, &(budget, line)) in &budgets {
        if budget > 0 && !waived.contains_key(rule.as_str()) {
            out.push(Finding::new(
                "waiver-ledger",
                &label,
                line,
                format!(
                    "`{rule}` budget is {budget} but nothing is \
                     waived — delete the ledger line"
                ),
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn waived(rule: &'static str, n: usize) -> Vec<Finding> {
        (0..n)
            .map(|i| {
                let mut f = Finding::new(
                    rule,
                    "rust/src/adios/wire.rs",
                    i as u32 + 1,
                    "x".into(),
                );
                f.waived = Some("reason".into());
                f
            })
            .collect()
    }

    fn ledger(tag: &str, body: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "pallas-lint-ledger-{}-{}",
            tag,
            std::process::id()
        ));
        std::fs::write(&p, body).unwrap();
        p
    }

    #[test]
    fn exact_budget_passes() {
        let p = ledger("ok", "# hardened-zone waivers\npanic-site 2\n");
        let mut f = waived("panic-site", 2);
        check(&p, &mut f).unwrap();
        assert_eq!(f.len(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn over_budget_fails() {
        let p = ledger("over", "panic-site 1\n");
        let mut f = waived("panic-site", 2);
        check(&p, &mut f).unwrap();
        assert!(f.iter().any(|x| x.rule == "waiver-ledger"
            && x.message.contains("budget is 1")));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn slack_budget_fails_the_ratchet() {
        let p = ledger("slack", "panic-site 3\n");
        let mut f = waived("panic-site", 1);
        check(&p, &mut f).unwrap();
        assert!(f.iter().any(|x| x.rule == "waiver-ledger"
            && x.message.contains("shrink")));
        // Budget with zero waived findings left behind fails too.
        let p2 = ledger("dead", "nested-lock 1\n");
        let mut f2 = Vec::new();
        check(&p2, &mut f2).unwrap();
        assert!(f2.iter().any(|x| x.rule == "waiver-ledger"
            && x.message.contains("delete the ledger line")));
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn unledgered_waiver_fails() {
        let p = ledger("none", "");
        let mut f = waived("lock-across-blocking", 1);
        check(&p, &mut f).unwrap();
        assert!(f.iter().any(|x| x.rule == "waiver-ledger"
            && x.message.contains("no `lock-across-blocking` line")));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn malformed_ledger_is_a_hard_error() {
        for bad in
            ["panic-site", "panic-site one", "no-such-rule 1",
             "panic-site 1 extra", "panic-site 1\npanic-site 2"]
        {
            let p = ledger("bad", bad);
            let err = check(&p, &mut Vec::new());
            assert!(err.is_err(), "{bad:?}");
            std::fs::remove_file(&p).ok();
        }
    }
}
