//! A Rust token scanner sufficient for `pallas-lint`'s rules.
//!
//! Not a full lexer: it distinguishes identifiers, integer/float
//! literals, string/char literals (contents dropped, so rule patterns
//! never fire inside quoted text), lifetimes, comments (retained so
//! `lint:allow` waiver directives can be parsed), and single-character
//! punctuation. Multi-character operators arrive as their component
//! punct tokens (`::` is `:` `:`), which is all the rules need.
//!
//! Handles the literal forms that appear in this crate: escapes in
//! string and char literals, raw strings `r"…"` / `r#"…"#` with any
//! number of hashes, byte strings `b"…"` / `br#"…"#`, nested block
//! comments, and the lifetime-vs-char-literal ambiguity after `'`.

/// One lexed token with the 1-based line it starts on.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub line: u32,
    /// Byte offset of the token's first byte in the source text.
    /// Strictly increasing across the token stream, which the
    /// concurrency pass relies on to order items within a file.
    pub pos: usize,
    pub kind: TokKind,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident(String),
    /// Single punctuation character.
    Punct(char),
    /// Numeric literal (verbatim text, e.g. `0x1f`, `3.5`, `1u64`).
    Num(String),
    /// String literal of any form; contents dropped.
    Str,
    /// Char literal; contents dropped.
    Char,
    /// Lifetime (`'a`).
    Lifetime,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(&self.kind, TokKind::Ident(i) if i == s)
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }

    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Ident(i) => Some(i),
            _ => None,
        }
    }

    pub fn num(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Num(n) => Some(n),
            _ => None,
        }
    }
}

/// A comment, kept out of the token stream (rules never match inside
/// comments) but retained for waiver-directive parsing.
#[derive(Clone, Debug, PartialEq)]
pub struct Comment {
    pub line: u32,
    /// Text without the `//` / `/*` markers, trimmed.
    pub text: String,
    /// True when the comment is the only thing on its source line
    /// (directives in such comments waive the *next* line).
    pub own_line: bool,
}

/// Lexer output: the token stream plus the comment side channel.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

/// Tokenize `src`. Never fails: unrecognized bytes are skipped (an
/// unterminated literal consumes to end of input), which is the right
/// degradation for a linter — rules simply see fewer tokens.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    // Byte offset where the current source line starts; used to decide
    // whether a comment has code before it on the same line.
    let mut line_start = 0usize;

    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
                line_start = i;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                let start = i + 2;
                let mut j = start;
                while j < b.len() && b[j] != b'\n' {
                    j += 1;
                }
                let own_line = src[line_start..i]
                    .chars()
                    .all(|ch| ch.is_whitespace());
                out.comments.push(Comment {
                    line,
                    text: src[start..j].trim().to_string(),
                    own_line,
                });
                i = j;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // Nested block comments, per Rust.
                let start_line = line;
                let text_start = i + 2;
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < b.len() && depth > 0 {
                    if b[j] == b'\n' {
                        line += 1;
                        line_start = j + 1;
                        j += 1;
                    } else if b[j] == b'/'
                        && j + 1 < b.len()
                        && b[j + 1] == b'*'
                    {
                        depth += 1;
                        j += 2;
                    } else if b[j] == b'*'
                        && j + 1 < b.len()
                        && b[j + 1] == b'/'
                    {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                let text_end = j.saturating_sub(2).max(text_start);
                out.comments.push(Comment {
                    line: start_line,
                    text: src[text_start..text_end].trim().to_string(),
                    own_line: false,
                });
                i = j;
            }
            b'"' => {
                let pos = i;
                i = skip_string(b, i, &mut line, &mut line_start);
                out.tokens.push(Token { line, pos, kind: TokKind::Str });
            }
            b'\'' => {
                // Lifetime or char literal.
                let (next, kind) =
                    lex_quote(b, i, &mut line, &mut line_start);
                out.tokens.push(Token { line, pos: i, kind });
                i = next;
            }
            _ if c == b'r' || c == b'b' => {
                // Possible raw/byte string prefix, else an identifier.
                if let Some(next) =
                    try_prefixed_string(b, i, &mut line, &mut line_start)
                {
                    out.tokens.push(Token {
                        line,
                        pos: i,
                        kind: TokKind::Str,
                    });
                    i = next;
                } else {
                    i = lex_ident(src, b, i, line, &mut out.tokens);
                }
            }
            _ if c == b'_' || c.is_ascii_alphabetic() => {
                i = lex_ident(src, b, i, line, &mut out.tokens);
            }
            _ if c.is_ascii_digit() => {
                let start = i;
                let mut j = i + 1;
                while j < b.len() {
                    let d = b[j];
                    if d == b'.' {
                        // `1..n` is a range, not a float.
                        if j + 1 < b.len() && b[j + 1] == b'.' {
                            break;
                        }
                        // `1.method()` — method call on a literal.
                        if j + 1 < b.len()
                            && (b[j + 1] == b'_'
                                || b[j + 1].is_ascii_alphabetic())
                        {
                            break;
                        }
                        j += 1;
                    } else if d == b'_'
                        || d.is_ascii_alphanumeric()
                    {
                        j += 1;
                    } else {
                        break;
                    }
                }
                out.tokens.push(Token {
                    line,
                    pos: start,
                    kind: TokKind::Num(src[start..j].to_string()),
                });
                i = j;
            }
            _ => {
                if c.is_ascii() {
                    out.tokens.push(Token {
                        line,
                        pos: i,
                        kind: TokKind::Punct(c as char),
                    });
                    i += 1;
                } else {
                    // Multi-byte UTF-8 (e.g. `µ` in a doc string that
                    // leaked here): skip the whole scalar.
                    let mut j = i + 1;
                    while j < b.len() && (b[j] & 0xC0) == 0x80 {
                        j += 1;
                    }
                    i = j;
                }
            }
        }
    }
    out
}

fn lex_ident(
    src: &str,
    b: &[u8],
    i: usize,
    line: u32,
    tokens: &mut Vec<Token>,
) -> usize {
    let start = i;
    let mut j = i + 1;
    while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
        j += 1;
    }
    tokens.push(Token {
        line,
        pos: start,
        kind: TokKind::Ident(src[start..j].to_string()),
    });
    j
}

/// Skip a `"…"` string starting at `i` (which points at the opening
/// quote). Returns the index after the closing quote.
fn skip_string(
    b: &[u8],
    i: usize,
    line: &mut u32,
    line_start: &mut usize,
) -> usize {
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            b'\n' => {
                *line += 1;
                j += 1;
                *line_start = j;
            }
            _ => j += 1,
        }
    }
    j
}

/// Raw / byte string starting at `i` if the prefix matches
/// (`r"`, `r#…#"`, `b"`, `br"`, `br#…#"`): returns the index after the
/// literal, or `None` when this is a plain identifier.
fn try_prefixed_string(
    b: &[u8],
    i: usize,
    line: &mut u32,
    line_start: &mut usize,
) -> Option<usize> {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
        if j < b.len() && b[j] == b'"' {
            return Some(skip_string(b, j, line, line_start));
        }
        if j >= b.len() || b[j] != b'r' {
            return None;
        }
    }
    // At `r`.
    j += 1;
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != b'"' {
        return None;
    }
    // Raw string: scan for `"` followed by `hashes` hashes; no escapes.
    j += 1;
    while j < b.len() {
        if b[j] == b'\n' {
            *line += 1;
            j += 1;
            *line_start = j;
            continue;
        }
        if b[j] == b'"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < b.len() && b[k] == b'#' && seen < hashes {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return Some(k);
            }
        }
        j += 1;
    }
    Some(j)
}

/// Disambiguate `'` at `i`: char literal vs lifetime. Returns the index
/// after the token and its kind.
fn lex_quote(
    b: &[u8],
    i: usize,
    line: &mut u32,
    line_start: &mut usize,
) -> (usize, TokKind) {
    let j = i + 1;
    if j >= b.len() {
        return (j, TokKind::Char);
    }
    if b[j] == b'\\' {
        // Escaped char literal: skip to the closing quote.
        let mut k = j + 2;
        while k < b.len() && b[k] != b'\'' {
            if b[k] == b'\n' {
                *line += 1;
                *line_start = k + 1;
            }
            k += 1;
        }
        return (k.saturating_add(1).min(b.len()), TokKind::Char);
    }
    if b[j] == b'_' || b[j].is_ascii_alphabetic() {
        // `'a'` is a char literal; `'a` (no closing quote after one
        // ident char run) is a lifetime.
        let mut k = j + 1;
        while k < b.len() && (b[k] == b'_' || b[k].is_ascii_alphanumeric())
        {
            k += 1;
        }
        if k < b.len() && b[k] == b'\'' && k == j + 1 {
            return (k + 1, TokKind::Char);
        }
        return (k, TokKind::Lifetime);
    }
    // Punctuation char literal like `'('` or `' '`.
    let mut k = j;
    while k < b.len() && b[k] != b'\'' && b[k] != b'\n' {
        k += 1;
    }
    if k < b.len() && b[k] == b'\'' {
        return (k + 1, TokKind::Char);
    }
    // Stray quote; treat as punct to make progress.
    (i + 1, TokKind::Punct('\''))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| t.ident().map(|s| s.to_string()))
            .collect()
    }

    #[test]
    fn basic_tokens_with_lines() {
        let l = lex("let x = 1;\nfoo.bar();\n");
        assert_eq!(
            l.tokens[0],
            Token {
                line: 1,
                pos: 0,
                kind: TokKind::Ident("let".into()),
            }
        );
        let bar = l
            .tokens
            .iter()
            .find(|t| t.is_ident("bar"))
            .expect("bar lexed");
        assert_eq!(bar.line, 2);
        assert_eq!(bar.pos, 15);
    }

    #[test]
    fn byte_offsets_are_strictly_monotone() {
        let src = "fn f<'a>(x: &'a str) { let s = \"q\"; a[0] = 'x'; }";
        let l = lex(src);
        let mut last = None;
        for t in &l.tokens {
            assert!(t.pos < src.len());
            if let Some(p) = last {
                assert!(t.pos > p, "offsets regressed: {} -> {}", p, t.pos);
            }
            last = Some(t.pos);
        }
    }

    #[test]
    fn strings_hide_their_contents() {
        // `.unwrap()` inside the string must not surface as tokens.
        let l = lex(r#"let s = "a.unwrap() call"; s.len();"#);
        assert!(!l.tokens.iter().any(|t| t.is_ident("unwrap")));
        assert!(l.tokens.iter().any(|t| t.is_ident("len")));
    }

    #[test]
    fn raw_and_byte_strings() {
        let src = "let a = r#\"panic!(\"x\")\"#; let b = b\"todo\"; \
                   let c = br#\"x\"#; rest";
        let ids = idents(src);
        assert!(!ids.contains(&"panic".to_string()));
        assert!(!ids.contains(&"todo".to_string()));
        assert!(ids.contains(&"rest".to_string()));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes = l
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .count();
        let chars = l
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Char)
            .count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let l = lex("x(); // lint:allow(panic-site): reason\n/* block\n\
                     unwrap */ y();");
        assert!(!l.tokens.iter().any(|t| t.is_ident("unwrap")));
        assert_eq!(l.comments.len(), 2);
        assert!(l.comments[0].text.starts_with("lint:allow"));
        assert!(!l.comments[0].own_line);
        assert_eq!(l.comments[0].line, 1);
    }

    #[test]
    fn own_line_comments_detected() {
        let l = lex("    // lint:allow(x): next line\nfoo();");
        assert!(l.comments[0].own_line);
    }

    #[test]
    fn numbers_and_ranges() {
        let l = lex("for i in 0..n { a[i] = 0x1f_u64; b = 1.5; }");
        let nums: Vec<&str> =
            l.tokens.iter().filter_map(|t| t.num()).collect();
        assert_eq!(nums, vec!["0", "0x1f_u64", "1.5"]);
    }

    #[test]
    fn nested_block_comments() {
        let l = lex("a /* x /* y */ z */ b");
        let ids = idents("a /* x /* y */ z */ b");
        assert_eq!(ids, vec!["a", "b"]);
        assert_eq!(l.comments.len(), 1);
    }
}
