//! Interprocedural concurrency analysis (the `lock-*` rule family).
//!
//! The crate's unattended service loops — SST writer serve threads,
//! fleet workers, staged fetch threads — share state behind a handful
//! of long-lived mutexes. A lock-order inversion between any two of
//! them is a production deadlock that no single-file rule can see, so
//! this pass models the whole crate at once:
//!
//! 1. **Class registry.** `util::sync::classes` declares every lock
//!    class as `static NAME: LockClass = LockClass { .., rank: N };`.
//!    The pass parses that table straight out of the token stream, so
//!    the static model and the debug-build runtime checker
//!    (`OrderedMutex`) can never drift apart.
//! 2. **Owner map.** Every `OrderedMutex::new(&classes::X, ..)` /
//!    `OrderedCondvar::new(&classes::X)` construction site is walked
//!    backwards to the field, `let`, or `static` that owns it, giving
//!    a crate-wide ident → class map (`shared` → `SST_WRITER_SHARED`).
//! 3. **Item table.** A lightweight parser collects every `fn` item
//!    and its body token range, building a crate-wide call-edge table
//!    on top of the lexer.
//! 4. **Dataflow walk.** Each body is walked with a live-guard stack
//!    (brace-scoped, killed by `drop(g)` / statement end), recording
//!    direct nesting edges, call sites made while guards are held, and
//!    `Condvar` waits. A fixpoint over the call graph yields
//!    `may_acquire` per function, turning held-across-call sites into
//!    interprocedural edges.
//!
//! Findings: `lock-order` (acquisition violating the rank order),
//! `lock-across-call` (a call that may transitively acquire a class at
//! or below a held rank), `lock-cycle` (a cycle in the combined
//! edge graph — deadlock between class orders), `condvar-class` (a
//! wait using a guard of the wrong class, or made while other locks
//! are held), and `unregistered-lock` (a raw `Mutex`/`Condvar` or an
//! unresolvable acquisition inside a [`LOCK_ZONES`] module).
//!
//! The computed graph is serialized to the blessed manifest
//! `tools/lint/lock.graph.json` (fingerprint-style, see
//! [`check_graph`]): growing an edge without re-blessing is a
//! `lock-graph` finding, so every new lock ordering is a reviewable
//! diff.
//!
//! Known limits, chosen to keep the walk lexer-level: guards bound by
//! `match` scrutinees live to the end of the enclosing statement
//! (slight over-approximation), call edges are matched by bare
//! function name (a method call resolves to every crate `fn` of that
//! name — except the std-shadowing method names in `DOTTED_EXCLUDE`,
//! which are never linked when invoked through `.`), and `Drop::drop`
//! bodies are scanned but never appear as callees.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::lexer::Token;
use super::{rules, Finding, SourceFile};
use crate::util::json::{self, Json};

/// Modules in which every `Mutex`/`Condvar` must carry a registered
/// lock class. Same path grammar as `HARDENED_ZONES`: entries ending
/// in `/` are directory prefixes. `util/sync.rs` itself is excluded —
/// it *implements* the wrappers.
pub const LOCK_ZONES: &[&str] = &[
    "rust/src/adios/sst/",
    "rust/src/adios/transport.rs",
    "rust/src/adios/multiplex.rs",
    "rust/src/pipeline/",
    "rust/src/runtime/mod.rs",
];

/// Is `rel` (repo-relative, `/`-separated) inside a lock zone?
pub fn in_lock_zone(rel: &str) -> bool {
    LOCK_ZONES.iter().any(|z| {
        if let Some(dir) = z.strip_suffix('/') {
            rel.strip_prefix(dir)
                .map(|rest| rest.starts_with('/'))
                .unwrap_or(false)
        } else {
            rel == *z
        }
    })
}

/// Acquisition helpers: a call to one of these IS an acquisition of
/// its first argument, handled at the call site.
const ACQUIRE_HELPERS: &[&str] = &["lock_or_poisoned", "lock_or_warn"];

/// Function names that are the locking machinery itself (or `drop`):
/// never treated as call edges, and the acquisition helpers' own
/// bodies are skipped — they are the implementation of acquisition,
/// not users of it.
const INTRINSICS: &[&str] = &[
    "lock",
    "try_lock",
    "lock_or_poisoned",
    "lock_or_warn",
    "wait_timeout",
    "wait_timeout_or_poisoned",
    "notify_one",
    "notify_all",
    "drop",
    "class",
];

/// Method names (call position after `.`) that shadow ubiquitous std
/// container/iterator/atomic methods. Call edges are matched by bare
/// name, so `sh.published.get(step)` would otherwise link to
/// `Engine::get` and every other crate `fn get` — these names are never
/// treated as crate call edges when invoked as methods. Free and
/// path-qualified calls (`Self::helper(..)`) still link normally.
const DOTTED_EXCLUDE: &[&str] = &[
    "get", "get_mut", "insert", "remove", "entry", "push", "pop",
    "len", "is_empty", "iter", "iter_mut", "keys", "values",
    "contains", "contains_key", "clone", "cloned", "copied",
    "collect", "map", "filter", "find", "any", "all", "min", "max",
    "sum", "take", "send", "recv", "load", "store", "join", "next",
    "extend", "drain",
];

/// One lock class parsed from the registry
/// (`static NAME: LockClass = LockClass { .., rank: N };`).
#[derive(Debug, Clone)]
pub struct ClassDef {
    /// The registry static's identifier (`SST_WRITER_SHARED`) — the
    /// stable name used in findings and the blessed graph.
    pub ident: String,
    pub rank: u32,
    pub file: String,
    pub line: u32,
}

/// One edge of the lock-order graph: while `from` was held, `to` was
/// acquired (kind `direct`) or a call was made that may acquire it
/// (kind `call`).
#[derive(Debug, Clone, PartialEq)]
pub struct Edge {
    pub kind: String,
    /// `file::fn` sites that induce the edge, sorted.
    pub sites: BTreeSet<String>,
}

/// The crate-wide lock-order graph.
#[derive(Debug, Default, PartialEq)]
pub struct LockGraph {
    /// Class ident → rank.
    pub classes: BTreeMap<String, u32>,
    /// (from ident, to ident) → edge facts.
    pub edges: BTreeMap<(String, String), Edge>,
}

/// A `fn` item with a body.
struct Item {
    name: String,
    line: u32,
    end_line: u32,
    /// Token index range of the body (inside the braces).
    body: (usize, usize),
}

/// Collect every `fn` item (with a body) in `sf`, including nested and
/// test functions. Trait method declarations without bodies are
/// skipped.
fn items(sf: &SourceFile) -> Vec<Item> {
    let t = &sf.tokens;
    let mut out = Vec::new();
    for i in 0..t.len() {
        if !t[i].is_ident("fn") {
            continue;
        }
        let Some(name) = t.get(i + 1).and_then(|x| x.ident()) else {
            continue;
        };
        let Some((b, e)) = rules::body_range(t, i + 2) else {
            continue;
        };
        let end_line = t
            .get(e)
            .or_else(|| t.last())
            .map(|x| x.line)
            .unwrap_or(t[i].line);
        out.push(Item {
            name: name.to_string(),
            line: t[i].line,
            end_line,
            body: (b, e),
        });
    }
    out
}

/// `(name, start_line, end_line)` for every `fn` item with a body —
/// used by the report layer to attach stable symbols to findings.
pub fn fn_spans(sf: &SourceFile) -> Vec<(String, u32, u32)> {
    items(sf)
        .into_iter()
        .map(|it| (it.name, it.line, it.end_line))
        .collect()
}

/// Parse the lock-class registry out of the token streams:
/// `static NAME : LockClass = .. rank : N .. ;` anywhere in the crate.
fn class_defs(sources: &[SourceFile]) -> Vec<ClassDef> {
    let mut out: Vec<ClassDef> = Vec::new();
    for sf in sources {
        let t = &sf.tokens;
        for i in 0..t.len() {
            if !t[i].is_ident("static") {
                continue;
            }
            let Some(name) = t.get(i + 1).and_then(|x| x.ident())
            else {
                continue;
            };
            if !(t.get(i + 2).map(|x| x.is_punct(':')).unwrap_or(false)
                && t.get(i + 3)
                    .map(|x| x.is_ident("LockClass"))
                    .unwrap_or(false))
            {
                continue;
            }
            let mut rank = None;
            let mut j = i + 4;
            while j < t.len() && !t[j].is_punct(';') {
                if t[j].is_ident("rank")
                    && t.get(j + 1)
                        .map(|x| x.is_punct(':'))
                        .unwrap_or(false)
                {
                    rank = t
                        .get(j + 2)
                        .and_then(|x| x.num())
                        .and_then(|n| {
                            n.replace('_', "").parse::<u32>().ok()
                        });
                }
                j += 1;
            }
            if let Some(rank) = rank {
                if !out.iter().any(|d| d.ident == name) {
                    out.push(ClassDef {
                        ident: name.to_string(),
                        rank,
                        file: sf.path.clone(),
                        line: t[i].line,
                    });
                }
            }
        }
    }
    out
}

/// Walk back from a construction site (`OrderedMutex::new(..)` at
/// token `ctor`) to the binding that owns it: a struct-literal field
/// (`shared: Arc::new(OrderedMutex::new(..))`), a `let`, or a
/// `static`/`const`. Returns `None` when no owner is found within the
/// statement.
fn owner_ident(t: &[Token], ctor: usize) -> Option<String> {
    let mut k = ctor;
    let mut steps = 0usize;
    while k > 0 && steps < 96 {
        k -= 1;
        steps += 1;
        let tk = &t[k];
        if tk.is_punct(';') || tk.is_punct('}') {
            return None;
        }
        if tk.is_ident("let") {
            let mut j = k + 1;
            if t.get(j).map(|x| x.is_ident("mut")).unwrap_or(false) {
                j += 1;
            }
            return t.get(j).and_then(|x| x.ident()).map(str::to_string);
        }
        if tk.is_ident("static") || tk.is_ident("const") {
            return t
                .get(k + 1)
                .and_then(|x| x.ident())
                .map(str::to_string);
        }
        if tk.is_punct(':')
            && !(k > 0 && t[k - 1].is_punct(':'))
            && !t.get(k + 1).map(|x| x.is_punct(':')).unwrap_or(false)
        {
            // Struct-literal field: `{` or `,` then `name` then `:`.
            if k >= 2 {
                if let Some(name) = t[k - 1].ident() {
                    if t[k - 2].is_punct('{') || t[k - 2].is_punct(',')
                    {
                        return Some(name.to_string());
                    }
                }
            }
            continue;
        }
        if tk.is_punct('{') {
            return None;
        }
    }
    None
}

/// Map every binding that owns an `OrderedMutex`/`OrderedCondvar` to
/// its class index. Constructions with an unresolvable class, and
/// idents bound to two different classes, are `unregistered-lock`
/// findings inside lock zones.
fn owner_map(
    sources: &[SourceFile],
    defs: &[ClassDef],
    out: &mut Vec<Finding>,
) -> BTreeMap<String, usize> {
    let idx: BTreeMap<&str, usize> = defs
        .iter()
        .enumerate()
        .map(|(i, d)| (d.ident.as_str(), i))
        .collect();
    let mut owners: BTreeMap<String, usize> = BTreeMap::new();
    for sf in sources {
        let t = &sf.tokens;
        let zone = in_lock_zone(&sf.path);
        for i in 0..t.len() {
            let is_ctor = (t[i].is_ident("OrderedMutex")
                || t[i].is_ident("OrderedCondvar"))
                && t.get(i + 1).map(|x| x.is_punct(':')).unwrap_or(false)
                && t.get(i + 2).map(|x| x.is_punct(':')).unwrap_or(false)
                && t.get(i + 3).map(|x| x.is_ident("new")).unwrap_or(false)
                && t.get(i + 4).map(|x| x.is_punct('(')).unwrap_or(false);
            if !is_ctor {
                continue;
            }
            let arg = rules::first_arg_expr(t, i + 4);
            let class = arg
                .rsplit('.')
                .next()
                .and_then(|seg| idx.get(seg))
                .copied();
            let Some(class) = class else {
                if zone && !sf.exempt[i] {
                    out.push(
                        Finding::new(
                            "unregistered-lock",
                            &sf.path,
                            t[i].line,
                            format!(
                                "ordered lock constructed with \
                                 unresolvable class `{arg}` — name a \
                                 `util::sync::classes` entry"
                            ),
                        )
                        .with_symbol(enclosing(sf, t[i].line)),
                    );
                }
                continue;
            };
            let Some(owner) = owner_ident(t, i) else {
                continue;
            };
            match owners.get(&owner) {
                Some(&prev) if prev != class => {
                    out.push(
                        Finding::new(
                            "unregistered-lock",
                            &sf.path,
                            t[i].line,
                            format!(
                                "`{owner}` is bound to two lock \
                                 classes (`{}` and `{}`) — class \
                                 resolution is by ident; rename one \
                                 binding",
                                defs[prev].ident, defs[class].ident
                            ),
                        )
                        .with_symbol(enclosing(sf, t[i].line)),
                    );
                }
                _ => {
                    owners.insert(owner, class);
                }
            }
        }
    }
    owners
}

/// Innermost enclosing `fn` name for a line, for finding symbols.
fn enclosing(sf: &SourceFile, line: u32) -> Option<String> {
    let mut best: Option<Item> = None;
    for it in items(sf) {
        if it.line <= line && line <= it.end_line {
            let deeper = best
                .as_ref()
                .map(|b| it.line >= b.line)
                .unwrap_or(true);
            if deeper {
                best = Some(it);
            }
        }
    }
    best.map(|b| b.name)
}

/// A live guard during the dataflow walk.
struct Live {
    binding: Option<String>,
    class: Option<usize>,
    depth: usize,
    /// Unbound guards die at the end of their statement.
    temp: bool,
}

/// A call made inside a function body.
struct CallSite {
    callee: String,
    /// Classes held (resolved guards only) when the call was made.
    held: Vec<usize>,
    line: u32,
}

/// A `Condvar` wait site.
struct Wait {
    cv_class: Option<usize>,
    cv_expr: String,
    guard_class: Option<usize>,
    guard_resolved: bool,
    /// Other resolved classes held during the wait.
    others: Vec<usize>,
    line: u32,
}

/// Everything the walk learned about one function body.
struct FnFacts {
    name: String,
    file: String,
    /// `file::fn` — the site label used in graph edges.
    site: String,
    /// Classes acquired directly (with the site line).
    direct: Vec<(usize, u32)>,
    /// Direct nesting: (held, acquired, line).
    nested: Vec<(usize, usize, u32)>,
    calls: Vec<CallSite>,
    waits: Vec<Wait>,
}

/// Binding that receives the value produced at token `start` (`start`
/// points at the first token of the RHS expression): walks back over
/// `=` and the pattern to the nearest plausible binding ident. Skips
/// type-ish idents (capitalized), path segments, and `mut`, so
/// `let Some(mut sh) = ..`, `let g: MutexGuard<T> = ..`, and plain
/// reassignment all resolve.
fn binding_before(t: &[Token], start: usize) -> Option<String> {
    if start == 0 || !t[start - 1].is_punct('=') {
        return None;
    }
    let mut k = start - 1;
    let mut steps = 0usize;
    while k > 0 && steps < 24 {
        k -= 1;
        steps += 1;
        let tk = &t[k];
        if tk.is_punct(';') || tk.is_punct('{') || tk.is_punct('}') {
            return None;
        }
        if tk.is_ident("let") {
            return None;
        }
        if let Some(id) = tk.ident() {
            if id == "mut" {
                continue;
            }
            if id.starts_with(char::is_uppercase) {
                continue;
            }
            let path_seg = (k >= 2
                && t[k - 1].is_punct(':')
                && t[k - 2].is_punct(':'))
                || (t.get(k + 1).map(|x| x.is_punct(':')).unwrap_or(false)
                    && t.get(k + 2)
                        .map(|x| x.is_punct(':'))
                        .unwrap_or(false));
            if path_seg {
                continue;
            }
            return Some(id.to_string());
        }
    }
    None
}

/// Resolve a normalized receiver/argument expression to a class index
/// by its last segment (`self.shared` → `shared`).
fn resolve(expr: &str, owners: &BTreeMap<String, usize>) -> Option<usize> {
    expr.rsplit('.').next().and_then(|seg| owners.get(seg)).copied()
}

/// All top-level argument expressions of a call; `open` is the index
/// of the `(`.
fn call_args(t: &[Token], open: usize) -> Vec<String> {
    let mut depth = 0usize;
    let mut args: Vec<String> = Vec::new();
    let mut cur: Vec<&Token> = Vec::new();
    for token in t.iter().skip(open) {
        if token.is_punct('(') {
            depth += 1;
            if depth == 1 {
                continue;
            }
        } else if token.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if token.is_punct(',') && depth == 1 {
            args.push(rules::expr_string(&cur));
            cur.clear();
            continue;
        }
        if depth >= 1 {
            cur.push(token);
        }
    }
    if !cur.is_empty() {
        args.push(rules::expr_string(&cur));
    }
    args
}

/// Walk one function body, producing facts. `out` receives
/// `unregistered-lock` findings for unresolvable acquisitions inside
/// lock zones.
fn scan_fn(
    sf: &SourceFile,
    item: &Item,
    owners: &BTreeMap<String, usize>,
    out: &mut Vec<Finding>,
) -> FnFacts {
    let t = &sf.tokens;
    let zone = in_lock_zone(&sf.path);
    let mut facts = FnFacts {
        name: item.name.clone(),
        file: sf.path.clone(),
        site: format!("{}::{}", sf.path, item.name),
        direct: Vec::new(),
        nested: Vec::new(),
        calls: Vec::new(),
        waits: Vec::new(),
    };
    let mut live: Vec<Live> = Vec::new();
    let mut depth = 0usize;
    let mut i = item.body.0;
    while i < item.body.1 {
        // Skip nested fn items — they are walked as their own entries.
        if t[i].is_ident("fn")
            && t.get(i + 1).map(|x| x.ident().is_some()).unwrap_or(false)
        {
            if let Some((_, e)) = rules::body_range(t, i + 2) {
                if e < item.body.1 {
                    i = e + 1;
                    continue;
                }
            }
        }
        if t[i].is_punct('{') {
            depth += 1;
            i += 1;
            continue;
        }
        if t[i].is_punct('}') {
            depth = depth.saturating_sub(1);
            live.retain(|g| g.depth <= depth);
            i += 1;
            continue;
        }
        if t[i].is_punct(';') {
            live.retain(|g| !(g.temp && g.depth == depth));
            i += 1;
            continue;
        }
        if sf.exempt[i] {
            i += 1;
            continue;
        }
        // `drop(name)` releases early.
        if t[i].is_ident("drop")
            && t.get(i + 1).map(|x| x.is_punct('(')).unwrap_or(false)
            && t.get(i + 3).map(|x| x.is_punct(')')).unwrap_or(false)
        {
            if let Some(name) = t.get(i + 2).and_then(|x| x.ident()) {
                live.retain(|g| g.binding.as_deref() != Some(name));
            }
            i += 1;
            continue;
        }
        // Acquisition via helper call or `.lock(`.
        let acq: Option<(usize, String, u32)> = if t[i]
            .ident()
            .map(|n| ACQUIRE_HELPERS.contains(&n))
            .unwrap_or(false)
            && t.get(i + 1).map(|x| x.is_punct('(')).unwrap_or(false)
        {
            Some((i, rules::first_arg_expr(t, i + 1), t[i].line))
        } else if t[i].is_punct('.')
            && t.get(i + 1).map(|x| x.is_ident("lock")).unwrap_or(false)
            && t.get(i + 2).map(|x| x.is_punct('(')).unwrap_or(false)
        {
            let (start, expr) = rules::lock_receiver(t, i);
            Some((start, expr, t[i + 1].line))
        } else {
            None
        };
        if let Some((start, expr, line)) = acq {
            let class = resolve(&expr, owners);
            if class.is_none() && zone && !expr.is_empty() {
                out.push(
                    Finding::new(
                        "unregistered-lock",
                        &sf.path,
                        line,
                        format!(
                            "acquisition of `{expr}` resolves to no \
                             registered lock class — wrap it in \
                             `OrderedMutex` with a `classes` entry"
                        ),
                    )
                    .with_symbol(Some(item.name.clone())),
                );
            }
            if let Some(b) = class {
                facts.direct.push((b, line));
                for g in &live {
                    if let Some(a) = g.class {
                        facts.nested.push((a, b, line));
                    }
                }
            }
            let binding = binding_before(t, start);
            live.push(Live {
                temp: binding.is_none(),
                binding,
                class,
                depth,
            });
            i += 1;
            continue;
        }
        // Condvar waits: `cv.wait_timeout(guard, ..)` or the legacy
        // `wait_timeout_or_poisoned(&cv, guard, ..)` helper.
        let wait: Option<(String, Option<String>, u32)> = if t[i]
            .is_punct('.')
            && t.get(i + 1)
                .map(|x| x.is_ident("wait_timeout"))
                .unwrap_or(false)
            && t.get(i + 2).map(|x| x.is_punct('(')).unwrap_or(false)
        {
            let (_, cv) = rules::lock_receiver(t, i);
            let args = call_args(t, i + 2);
            (!cv.is_empty()).then(|| {
                (cv, args.first().cloned(), t[i + 1].line)
            })
        } else if t[i].is_ident("wait_timeout_or_poisoned")
            && t.get(i + 1).map(|x| x.is_punct('(')).unwrap_or(false)
        {
            let args = call_args(t, i + 1);
            args.first().cloned().map(|cv| {
                (cv, args.get(1).cloned(), t[i].line)
            })
        } else {
            None
        };
        if let Some((cv_expr, guard_expr, line)) = wait {
            let guard_name = guard_expr
                .as_deref()
                .and_then(|e| e.rsplit('.').next())
                .map(str::to_string);
            let guard = guard_name.as_deref().and_then(|n| {
                live.iter()
                    .rev()
                    .find(|g| g.binding.as_deref() == Some(n))
            });
            let guard_class = guard.and_then(|g| g.class);
            let guard_resolved = guard.is_some();
            let others = live
                .iter()
                .filter(|g| {
                    g.binding.as_deref() != guard_name.as_deref()
                })
                .filter_map(|g| g.class)
                .collect();
            facts.waits.push(Wait {
                cv_class: resolve(&cv_expr, owners),
                cv_expr,
                guard_class,
                guard_resolved,
                others,
                line,
            });
            i += 1;
            continue;
        }
        // Call site: `name(` in call position. Filtered against the
        // crate fn table later; intrinsics never become edges.
        if let Some(name) = t[i].ident() {
            let dotted = i > 0 && t[i - 1].is_punct('.');
            if t.get(i + 1).map(|x| x.is_punct('(')).unwrap_or(false)
                && !INTRINSICS.contains(&name)
                && !(dotted && DOTTED_EXCLUDE.contains(&name))
                && !(i > 0 && t[i - 1].is_ident("fn"))
            {
                let held: Vec<usize> = {
                    let mut h: Vec<usize> =
                        live.iter().filter_map(|g| g.class).collect();
                    h.dedup();
                    h
                };
                facts.calls.push(CallSite {
                    callee: name.to_string(),
                    held,
                    line: t[i].line,
                });
            }
        }
        i += 1;
    }
    facts
}

/// Flag raw `Mutex::new` / `Condvar::new` constructions inside lock
/// zones — every lock there must carry a class.
fn raw_ctor_scan(sf: &SourceFile, out: &mut Vec<Finding>) {
    if !in_lock_zone(&sf.path) {
        return;
    }
    let t = &sf.tokens;
    for i in 0..t.len() {
        if sf.exempt[i] {
            continue;
        }
        let raw = (t[i].is_ident("Mutex") || t[i].is_ident("Condvar"))
            && t.get(i + 1).map(|x| x.is_punct(':')).unwrap_or(false)
            && t.get(i + 2).map(|x| x.is_punct(':')).unwrap_or(false)
            && t.get(i + 3)
                .map(|x| x.is_ident("new") || x.is_ident("default"))
                .unwrap_or(false);
        if raw {
            out.push(
                Finding::new(
                    "unregistered-lock",
                    &sf.path,
                    t[i].line,
                    format!(
                        "raw `{}` constructed in a lock zone — use \
                         `util::sync::Ordered{}` with a registered \
                         class so the order checker sees it",
                        t[i].ident().unwrap_or("?"),
                        t[i].ident().unwrap_or("?"),
                    ),
                )
                .with_symbol(enclosing(sf, t[i].line)),
            );
        }
    }
}

/// Run the whole pass: returns the computed lock-order graph and
/// pushes findings. The graph is what `--bless` records and
/// [`check_graph`] compares.
pub fn analyze(
    sources: &[SourceFile],
    out: &mut Vec<Finding>,
) -> LockGraph {
    let defs = class_defs(sources);
    let owners = owner_map(sources, &defs, out);
    for sf in sources {
        raw_ctor_scan(sf, out);
    }

    let mut all_facts: Vec<FnFacts> = Vec::new();
    for sf in sources {
        for item in items(sf) {
            if ACQUIRE_HELPERS.contains(&item.name.as_str())
                || INTRINSICS.contains(&item.name.as_str())
            {
                continue;
            }
            all_facts.push(scan_fn(sf, &item, &owners, out));
        }
    }

    // Call graph + may-acquire fixpoint, merged by bare fn name.
    let mut direct: BTreeMap<&str, BTreeSet<usize>> = BTreeMap::new();
    let mut callees: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for f in &all_facts {
        let d = direct.entry(&f.name).or_default();
        d.extend(f.direct.iter().map(|(c, _)| *c));
        let cs = callees.entry(&f.name).or_default();
        cs.extend(f.calls.iter().map(|c| c.callee.as_str()));
    }
    let mut may: BTreeMap<&str, BTreeSet<usize>> = direct.clone();
    loop {
        let mut grew = false;
        for (name, cs) in &callees {
            let mut add: BTreeSet<usize> = BTreeSet::new();
            for callee in cs {
                if let Some(m) = may.get(callee) {
                    add.extend(m.iter().copied());
                }
            }
            let cur = may.entry(*name).or_default();
            let before = cur.len();
            cur.extend(add);
            grew |= cur.len() != before;
        }
        if !grew {
            break;
        }
    }

    // Findings + edges.
    let mut graph = LockGraph {
        classes: defs
            .iter()
            .map(|d| (d.ident.clone(), d.rank))
            .collect(),
        edges: BTreeMap::new(),
    };
    let mut add_edge = |graph: &mut LockGraph,
                        a: usize,
                        b: usize,
                        kind: &str,
                        site: &str| {
        let key = (defs[a].ident.clone(), defs[b].ident.clone());
        let e = graph.edges.entry(key).or_insert_with(|| Edge {
            kind: kind.to_string(),
            sites: BTreeSet::new(),
        });
        if kind == "direct" {
            e.kind = "direct".to_string();
        }
        e.sites.insert(site.to_string());
    };
    for f in &all_facts {
        for &(a, b, line) in &f.nested {
            add_edge(&mut graph, a, b, "direct", &f.site);
            if defs[b].rank <= defs[a].rank {
                out.push(
                    Finding::new(
                        "lock-order",
                        &f.file,
                        line,
                        format!(
                            "`{}` (rank {}) acquired while `{}` \
                             (rank {}) is held — lock ranks must \
                             strictly increase",
                            defs[b].ident,
                            defs[b].rank,
                            defs[a].ident,
                            defs[a].rank,
                        ),
                    )
                    .with_symbol(Some(f.name.clone())),
                );
            }
        }
        for call in &f.calls {
            if call.held.is_empty()
                || INTRINSICS.contains(&call.callee.as_str())
            {
                continue;
            }
            let Some(acq) = may.get(call.callee.as_str()) else {
                continue;
            };
            for &a in &call.held {
                for &b in acq {
                    add_edge(&mut graph, a, b, "call", &f.site);
                    if defs[b].rank <= defs[a].rank {
                        out.push(
                            Finding::new(
                                "lock-across-call",
                                &f.file,
                                call.line,
                                format!(
                                    "call to `{}` may acquire `{}` \
                                     (rank {}) while `{}` (rank {}) \
                                     is held — release first or \
                                     re-rank",
                                    call.callee,
                                    defs[b].ident,
                                    defs[b].rank,
                                    defs[a].ident,
                                    defs[a].rank,
                                ),
                            )
                            .with_symbol(Some(f.name.clone())),
                        );
                    }
                }
            }
        }
        for w in &f.waits {
            let Some(cv) = w.cv_class else {
                continue;
            };
            if w.guard_resolved {
                if let Some(g) = w.guard_class {
                    if g != cv {
                        out.push(
                            Finding::new(
                                "condvar-class",
                                &f.file,
                                w.line,
                                format!(
                                    "waiting on condvar `{}` (class \
                                     `{}`) with a guard of class \
                                     `{}` — the wait would release \
                                     the wrong lock",
                                    w.cv_expr,
                                    defs[cv].ident,
                                    defs[g].ident,
                                ),
                            )
                            .with_symbol(Some(f.name.clone())),
                        );
                    }
                }
            }
            for &o in &w.others {
                out.push(
                    Finding::new(
                        "condvar-class",
                        &f.file,
                        w.line,
                        format!(
                            "waiting on condvar `{}` while also \
                             holding `{}` — the extra lock stays \
                             held for the whole wait",
                            w.cv_expr, defs[o].ident,
                        ),
                    )
                    .with_symbol(Some(f.name.clone())),
                );
            }
        }
    }

    cycle_findings(&graph, out);
    graph
}

/// Detect strongly-connected components with more than one node (or a
/// self-loop) in the class graph — each is a potential deadlock cycle.
fn cycle_findings(graph: &LockGraph, out: &mut Vec<Finding>) {
    let nodes: Vec<&str> =
        graph.classes.keys().map(String::as_str).collect();
    let succ = |n: &str| -> Vec<&str> {
        graph
            .edges
            .keys()
            .filter(|(f, _)| f == n)
            .map(|(_, t)| t.as_str())
            .collect()
    };
    // Iterative Kosaraju would be overkill for a handful of classes:
    // a node is in a cycle iff it can reach itself.
    let reaches = |from: &str, to: &str| -> bool {
        let mut seen: BTreeSet<&str> = BTreeSet::new();
        let mut stack = succ(from);
        while let Some(n) = stack.pop() {
            if n == to {
                return true;
            }
            if seen.insert(n) {
                stack.extend(succ(n));
            }
        }
        false
    };
    let mut reported: BTreeSet<String> = BTreeSet::new();
    for &n in &nodes {
        if !reaches(n, n) {
            continue;
        }
        // Every cycle member reaches n and vice versa; report the
        // whole component once, keyed by its sorted member list.
        let members: Vec<&str> = nodes
            .iter()
            .copied()
            .filter(|&m| m == n || (reaches(n, m) && reaches(m, n)))
            .collect();
        let key = members.join(",");
        if !reported.insert(key) {
            continue;
        }
        let involved: Vec<String> = graph
            .edges
            .iter()
            .filter(|((f, t), _)| {
                members.contains(&f.as_str())
                    && members.contains(&t.as_str())
            })
            .map(|((f, t), e)| {
                format!(
                    "{} -> {} ({})",
                    f,
                    t,
                    e.sites
                        .iter()
                        .cloned()
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
            .collect();
        let file = graph
            .edges
            .iter()
            .find(|((f, _), _)| members.contains(&f.as_str()))
            .and_then(|(_, e)| e.sites.iter().next())
            .and_then(|s| s.split("::").next())
            .unwrap_or("")
            .to_string();
        out.push(Finding::new(
            "lock-cycle",
            &file,
            0,
            format!(
                "lock-order inversion cycle between {{{}}}: {} — \
                 threads taking these in different orders deadlock",
                members.join(", "),
                involved.join("; "),
            ),
        ));
    }
}

impl LockGraph {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert(
            "classes".into(),
            Json::Obj(
                self.classes
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                    .collect(),
            ),
        );
        let edges = self
            .edges
            .iter()
            .map(|((from, to), e)| {
                let mut eo = BTreeMap::new();
                eo.insert("from".into(), Json::Str(from.clone()));
                eo.insert("to".into(), Json::Str(to.clone()));
                eo.insert("kind".into(), Json::Str(e.kind.clone()));
                eo.insert(
                    "sites".into(),
                    Json::Arr(
                        e.sites
                            .iter()
                            .map(|s| Json::Str(s.clone()))
                            .collect(),
                    ),
                );
                Json::Obj(eo)
            })
            .collect();
        o.insert("edges".into(), Json::Arr(edges));
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<LockGraph> {
        let mut classes = BTreeMap::new();
        for (k, v) in j
            .get("classes")
            .and_then(|c| c.as_obj())
            .ok_or_else(|| anyhow!("lock graph missing `classes`"))?
        {
            classes.insert(
                k.clone(),
                v.as_u64().map(|r| r as u32).ok_or_else(|| {
                    anyhow!("lock graph class `{k}` rank not an integer")
                })?,
            );
        }
        let mut edges = BTreeMap::new();
        for e in j
            .get("edges")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow!("lock graph missing `edges`"))?
        {
            let s = |k: &str| -> Result<String> {
                Ok(e.get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| {
                        anyhow!("lock graph edge missing `{k}`")
                    })?
                    .to_string())
            };
            let sites = e
                .get("sites")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("lock graph edge missing `sites`"))?
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect();
            edges.insert(
                (s("from")?, s("to")?),
                Edge { kind: s("kind")?, sites },
            );
        }
        Ok(LockGraph { classes, edges })
    }
}

/// Manifest label used for `lock-graph` findings (relative, stable).
const GRAPH_LABEL: &str = "tools/lint/lock.graph.json";

/// Compare the computed graph against the blessed manifest. Every
/// difference — a new edge, a vanished edge, a class change — is a
/// `lock-graph` finding: new lock orderings only land via an explicit,
/// reviewed `--bless` diff.
pub fn check_graph(
    manifest: &Path,
    graph: &LockGraph,
    out: &mut Vec<Finding>,
) -> Result<()> {
    let text = match std::fs::read_to_string(manifest) {
        Ok(t) => t,
        Err(_) => {
            out.push(Finding::new(
                "lock-graph",
                GRAPH_LABEL,
                0,
                "lock-order graph manifest missing — run \
                 `pallas-lint --bless` and commit it"
                    .to_string(),
            ));
            return Ok(());
        }
    };
    let recorded = LockGraph::from_json(
        &json::parse(&text)
            .map_err(|e| anyhow!("parsing lock graph manifest: {e}"))?,
    )?;
    if recorded.classes != graph.classes {
        let describe = |m: &BTreeMap<String, u32>| {
            m.iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        out.push(Finding::new(
            "lock-graph",
            GRAPH_LABEL,
            0,
            format!(
                "lock classes changed: recorded [{}], current [{}] — \
                 review the ranks and run `pallas-lint --bless`",
                describe(&recorded.classes),
                describe(&graph.classes),
            ),
        ));
    }
    for ((from, to), e) in &graph.edges {
        match recorded.edges.get(&(from.clone(), to.clone())) {
            None => out.push(Finding::new(
                "lock-graph",
                GRAPH_LABEL,
                0,
                format!(
                    "new lock-order edge {from} -> {to} ({}, via {}) \
                     — review the ordering and run `pallas-lint \
                     --bless`",
                    e.kind,
                    e.sites
                        .iter()
                        .cloned()
                        .collect::<Vec<_>>()
                        .join(", "),
                ),
            )),
            Some(r) if r != e => out.push(Finding::new(
                "lock-graph",
                GRAPH_LABEL,
                0,
                format!(
                    "lock-order edge {from} -> {to} changed (kind or \
                     sites) — run `pallas-lint --bless` to re-record"
                ),
            )),
            Some(_) => {}
        }
    }
    for (from, to) in recorded.edges.keys() {
        if !graph.edges.contains_key(&(from.clone(), to.clone())) {
            out.push(Finding::new(
                "lock-graph",
                GRAPH_LABEL,
                0,
                format!(
                    "recorded lock-order edge {from} -> {to} no \
                     longer observed — run `pallas-lint --bless` to \
                     shrink the graph"
                ),
            ));
        }
    }
    Ok(())
}

/// Write the computed graph as the blessed manifest.
pub fn write_graph(manifest: &Path, graph: &LockGraph) -> Result<String> {
    if let Some(dir) = manifest.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    let mut body = graph.to_json().to_string_pretty();
    body.push('\n');
    std::fs::write(manifest, body)
        .with_context(|| format!("writing {}", manifest.display()))?;
    Ok(format!("lock graph written: {}", manifest.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const REG: &str = "
pub struct LockClass { pub name: &'static str, pub rank: u32 }
pub mod classes {
    pub static ALPHA: LockClass =
        LockClass { name: \"alpha\", rank: 10 };
    pub static BETA: LockClass =
        LockClass { name: \"beta\", rank: 20 };
}
";

    fn run(files: &[(&str, &str)]) -> (Vec<Finding>, LockGraph) {
        let mut sources =
            vec![SourceFile::parse("rust/src/util/sync.rs", REG)];
        for (path, src) in files {
            sources.push(SourceFile::parse(path, src));
        }
        let mut out = Vec::new();
        let graph = analyze(&sources, &mut out);
        (out, graph)
    }

    fn rules_of(f: &[Finding]) -> Vec<&'static str> {
        f.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn registry_and_owner_map_extracted() {
        let src = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32> }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0) }
}
fn ordered(s: &S) {
    let ga = s.a.lock();
    let gb = s.b.lock();
}
";
        let (f, g) = run(&[("rust/src/adios/sst/x.rs", src)]);
        assert_eq!(rules_of(&f), Vec::<&str>::new());
        assert_eq!(g.classes.get("ALPHA"), Some(&10));
        assert_eq!(g.classes.get("BETA"), Some(&20));
        let e = g
            .edges
            .get(&("ALPHA".to_string(), "BETA".to_string()))
            .expect("direct edge recorded");
        assert_eq!(e.kind, "direct");
        assert!(e
            .sites
            .contains("rust/src/adios/sst/x.rs::ordered"));
    }

    #[test]
    fn inversion_is_lock_order_and_cycle() {
        let src = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32> }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0) }
}
fn good(s: &S) { let ga = s.a.lock(); let gb = s.b.lock(); }
fn bad(s: &S) { let gb = s.b.lock(); let ga = s.a.lock(); }
";
        let (f, g) = run(&[("rust/src/adios/sst/x.rs", src)]);
        let mut r = rules_of(&f);
        r.sort();
        assert_eq!(r, ["lock-cycle", "lock-order"]);
        let order = f.iter().find(|x| x.rule == "lock-order").unwrap();
        assert_eq!(order.symbol.as_deref(), Some("bad"));
        assert!(g
            .edges
            .contains_key(&("BETA".to_string(), "ALPHA".to_string())));
    }

    #[test]
    fn guard_drop_ends_the_nesting() {
        let src = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32> }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0) }
}
fn f(s: &S) {
    let gb = s.b.lock();
    drop(gb);
    let ga = s.a.lock();
}
fn scoped(s: &S) {
    { let gb = s.b.lock(); }
    let ga = s.a.lock();
}
";
        let (f, g) = run(&[("rust/src/adios/sst/x.rs", src)]);
        assert_eq!(rules_of(&f), Vec::<&str>::new());
        assert!(g.edges.is_empty());
    }

    #[test]
    fn interprocedural_acquisition_via_call_edge() {
        let src = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32> }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0) }
}
fn takes_alpha(s: &S) { let ga = s.a.lock(); }
fn outer(s: &S) {
    let gb = s.b.lock();
    takes_alpha(s);
}
";
        let (f, g) = run(&[("rust/src/adios/sst/x.rs", src)]);
        assert_eq!(rules_of(&f), ["lock-across-call", "lock-cycle"]);
        let e = g
            .edges
            .get(&("BETA".to_string(), "ALPHA".to_string()))
            .expect("call edge");
        assert_eq!(e.kind, "call");
        assert!(e.sites.contains("rust/src/adios/sst/x.rs::outer"));
        // The rank-respecting direction draws no finding.
        let ok = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32> }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0) }
}
fn takes_beta(s: &S) { let gb = s.b.lock(); }
fn outer(s: &S) {
    let ga = s.a.lock();
    takes_beta(s);
}
";
        let (f, g) = run(&[("rust/src/adios/sst/x.rs", ok)]);
        assert_eq!(rules_of(&f), Vec::<&str>::new());
        assert_eq!(
            g.edges
                .get(&("ALPHA".to_string(), "BETA".to_string()))
                .map(|e| e.kind.as_str()),
            Some("call")
        );
    }

    #[test]
    fn std_shadowing_method_calls_draw_no_edge() {
        // `fn get` stands in for `Engine::get`: a crate function whose
        // name collides with the ubiquitous container method. Calling
        // `.get(..)` on guarded data must not link to it; a free call
        // of the same name still does.
        let src = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32> }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0) }
}
fn get(s: &S) { let ga = s.a.lock(); }
fn method_position(s: &S) {
    let gb = s.b.lock();
    let hit = gb.get(7);
}
";
        let (f, g) = run(&[("rust/src/adios/sst/x.rs", src)]);
        assert_eq!(rules_of(&f), Vec::<&str>::new());
        assert!(g.edges.is_empty());

        let free = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32> }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0) }
}
fn get(s: &S) { let ga = s.a.lock(); }
fn free_position(s: &S) {
    let gb = s.b.lock();
    get(s);
}
";
        let (f, g) = run(&[("rust/src/adios/sst/x.rs", free)]);
        assert_eq!(rules_of(&f), ["lock-across-call", "lock-cycle"]);
        assert!(g
            .edges
            .contains_key(&("BETA".to_string(), "ALPHA".to_string())));
    }

    #[test]
    fn condvar_wrong_class_and_extra_guard_flagged() {
        let src = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32>,
           cv: OrderedCondvar }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0),
        cv: OrderedCondvar::new(&classes::BETA) }
}
fn wrong(s: &S) {
    let ga = s.a.lock();
    let r = s.cv.wait_timeout(ga, timeout);
}
";
        let (f, _) = run(&[("rust/src/adios/sst/x.rs", src)]);
        assert_eq!(rules_of(&f), ["condvar-class"]);
        assert!(f[0].message.contains("wrong lock"), "{}", f[0].message);

        let extra = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32>,
           cv: OrderedCondvar }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0),
        cv: OrderedCondvar::new(&classes::BETA) }
}
fn holds_extra(s: &S) {
    let ga = s.a.lock();
    let gb = s.b.lock();
    let r = s.cv.wait_timeout(gb, timeout);
}
";
        let (f, _) = run(&[("rust/src/adios/sst/x.rs", extra)]);
        assert_eq!(rules_of(&f), ["condvar-class"]);
        assert!(f[0].message.contains("also"), "{}", f[0].message);

        let ok = "
struct S { b: OrderedMutex<u32>, cv: OrderedCondvar }
fn build() -> S {
    S { b: OrderedMutex::new(&classes::BETA, 0),
        cv: OrderedCondvar::new(&classes::BETA) }
}
fn fine(s: &S) {
    let gb = s.b.lock();
    let r = s.cv.wait_timeout(gb, timeout);
}
";
        let (f, _) = run(&[("rust/src/adios/sst/x.rs", ok)]);
        assert_eq!(rules_of(&f), Vec::<&str>::new());
    }

    #[test]
    fn unregistered_locks_flagged_in_zones_only() {
        let src = "
fn f() {
    let m = Mutex::new(0);
    let g = m.lock();
}
";
        let (f, _) = run(&[("rust/src/adios/sst/x.rs", src)]);
        let r = rules_of(&f);
        assert_eq!(r, ["unregistered-lock", "unregistered-lock"]);
        // Outside a lock zone the same code is silent.
        let (f, _) = run(&[("rust/src/util/stats.rs", src)]);
        assert_eq!(rules_of(&f), Vec::<&str>::new());
        // Test code inside a zone is exempt.
        let test_src = "#[cfg(test)]\nmod t {\nfn f() {\n    \
                        let m = Mutex::new(0);\n    \
                        let g = m.lock();\n}\n}\n";
        let (f, _) = run(&[("rust/src/adios/sst/x.rs", test_src)]);
        assert_eq!(rules_of(&f), Vec::<&str>::new());
    }

    #[test]
    fn graph_round_trips_and_drift_is_found() {
        let src = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32> }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0) }
}
fn ordered(s: &S) { let ga = s.a.lock(); let gb = s.b.lock(); }
";
        let (_, g) = run(&[("rust/src/adios/sst/x.rs", src)]);
        let back = LockGraph::from_json(
            &json::parse(&g.to_json().to_string_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(back, g);

        let dir = std::env::temp_dir().join(format!(
            "pallas-lint-lg-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = dir.join("lock.graph.json");

        // Missing manifest is a finding, not an error.
        let mut f = Vec::new();
        check_graph(&manifest, &g, &mut f).unwrap();
        assert_eq!(rules_of(&f), ["lock-graph"]);
        assert!(f[0].message.contains("--bless"));

        // Blessed graph checks clean.
        write_graph(&manifest, &g).unwrap();
        let mut f = Vec::new();
        check_graph(&manifest, &g, &mut f).unwrap();
        assert_eq!(rules_of(&f), Vec::<&str>::new());

        // A grown edge without re-blessing is drift.
        let mut grown = LockGraph {
            classes: g.classes.clone(),
            edges: g.edges.clone(),
        };
        grown.edges.insert(
            ("BETA".into(), "ALPHA".into()),
            Edge {
                kind: "direct".into(),
                sites: ["x.rs::f".to_string()].into_iter().collect(),
            },
        );
        let mut f = Vec::new();
        check_graph(&manifest, &grown, &mut f).unwrap();
        assert_eq!(rules_of(&f), ["lock-graph"]);
        assert!(f[0].message.contains("new lock-order edge"));

        // A vanished edge is drift too (shrink must re-bless).
        let empty = LockGraph {
            classes: g.classes.clone(),
            edges: BTreeMap::new(),
        };
        let mut f = Vec::new();
        check_graph(&manifest, &empty, &mut f).unwrap();
        assert_eq!(rules_of(&f), ["lock-graph"]);
        assert!(f[0].message.contains("no longer observed"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn let_else_and_match_bindings_resolve() {
        let src = "
struct S { a: OrderedMutex<u32>, b: OrderedMutex<u32> }
fn build() -> S {
    S { a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0) }
}
fn f(s: &S) {
    let Some(mut gb) = lock_or_warn(&s.b, \"b\") else { return };
    let ga = s.a.lock();
}
";
        let (f, _) = run(&[("rust/src/adios/sst/x.rs", src)]);
        let mut r = rules_of(&f);
        r.sort();
        assert_eq!(r, ["lock-cycle", "lock-order"]);
    }
}
