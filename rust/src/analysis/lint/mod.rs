//! `pallas-lint`: the crate's own static-analysis gate.
//!
//! In the paper's loosely-coupled streaming setups a single panicking
//! producer or reader tears down every coupled peer mid-stream — there
//! is no filesystem to fall back to — so the crate-wide invariants the
//! engine contract relies on (decode paths return typed errors, failed
//! `perform_gets` poisons handles, wire lengths are validated before
//! allocation) must hold *everywhere*, not just where a reviewer
//! looked. This module is a hand-rolled (dependency-free) lexer-level
//! scanner over the crate's own sources enforcing them statically,
//! wired into CI through the `pallas-lint` binary (`tools/`).
//!
//! ## Rule families
//!
//! **Panic-freedom zones** (hardened modules only — see
//! [`HARDENED_ZONES`]): `unwrap`/`expect` calls, `panic!`/`todo!`/
//! `unimplemented!`/`unreachable!`, integer-literal slice indexing, and
//! narrowing `as` casts are findings (`panic-site`, `index-literal`,
//! `narrow-cast`) unless inside `#[cfg(test)]` / `#[cfg(debug_assertions)]`
//! or waived.
//!
//! **Lock discipline**: `.lock().unwrap()` swallows poison anywhere in
//! the crate (`lock-unwrap` — use [`crate::util::sync::lock_or_poisoned`]);
//! inside hardened zones, holding a named lock guard across a blocking
//! call (`lock-across-blocking`) or re-acquiring the same mutex while
//! its guard is live (`nested-lock`) are findings.
//!
//! **Engine-contract conformance**: `impl Engine for ...` blocks must
//! not override the eager `put`/`get` trait defaults
//! (`engine-override`), and any `perform_gets` body that drains the
//! deferred queue must reach `fail_batch`/`poison` on failure
//! (`performgets-discipline`).
//!
//! **Escape + format hygiene**: `#[allow(...)]` attributes outside test
//! code are findings (`allow-escape` — justify with a waiver or fix the
//! code), and the wire/BP format fingerprint must match the committed
//! manifest (`format-fingerprint`, see [`fingerprint`]).
//!
//! **Interprocedural concurrency** (see [`concurrency`]): a crate-wide
//! pass resolves every `OrderedMutex`/`OrderedCondvar` to its
//! registered lock class, tracks live guards through call edges, and
//! builds the lock-order graph. Rank inversions (`lock-order`), calls
//! that may transitively acquire out of order while a guard is held
//! (`lock-across-call`), deadlock cycles (`lock-cycle`), `Condvar`
//! waits with the wrong guard class (`condvar-class`), classless locks
//! in lock zones (`unregistered-lock`), and drift against the blessed
//! `tools/lint/lock.graph.json` (`lock-graph`) are findings.
//!
//! ## Waiver grammar
//!
//! A finding is waived by an inline comment directive on the same line,
//! or alone on the line directly above:
//!
//! ```text
//! // lint:allow(<rule>): <reason>
//! ```
//!
//! The reason is mandatory; a directive with an unknown rule or a
//! missing reason is itself a finding (`bad-waiver`), and a directive
//! that waives nothing is one too (`stale-waiver`) — waivers cannot
//! rot in place. Every waived finding must additionally fit the
//! committed budget in `tools/lint/waivers.ledger`; the budget can only
//! shrink (see [`waivers`]).

pub mod concurrency;
pub mod fingerprint;
pub mod lexer;
pub mod rules;
pub mod waivers;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Rule identifiers a waiver directive may name.
pub const RULES: &[&str] = &[
    "panic-site",
    "index-literal",
    "narrow-cast",
    "lock-unwrap",
    "lock-across-blocking",
    "nested-lock",
    "engine-override",
    "performgets-discipline",
    "allow-escape",
    "format-fingerprint",
    "lock-order",
    "lock-cycle",
    "lock-across-call",
    "condvar-class",
    "unregistered-lock",
    "lock-graph",
];

/// Panic-freedom zones, as paths relative to the repository root.
/// Entries ending in `/` are directory prefixes. These are the modules
/// a corrupt peer or file reaches directly: every panic here is a
/// stream teardown in production.
pub const HARDENED_ZONES: &[&str] = &[
    "rust/src/adios/wire.rs",
    "rust/src/adios/bp.rs",
    "rust/src/adios/sst/",
    "rust/src/adios/multiplex.rs",
    "rust/src/adios/transport.rs",
    "rust/src/pipeline/",
    "rust/src/util/sync.rs",
];

/// Is `rel` (repo-relative, `/`-separated) inside a hardened zone?
pub fn is_hardened(rel: &str) -> bool {
    HARDENED_ZONES.iter().any(|z| {
        if let Some(dir) = z.strip_suffix('/') {
            rel.strip_prefix(dir)
                .map(|rest| rest.starts_with('/'))
                .unwrap_or(false)
        } else {
            rel == *z
        }
    })
}

/// One lint finding.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub rule: &'static str,
    /// Repo-relative path, `/`-separated.
    pub file: String,
    /// 1-based; 0 for file-level findings (fingerprint, ledger).
    pub line: u32,
    pub message: String,
    /// The waiver reason when an inline directive covers this finding.
    pub waived: Option<String>,
    /// Enclosing `fn` name, when known — part of the stable finding ID
    /// so CI artifact diffs don't churn on unrelated line shifts.
    pub symbol: Option<String>,
}

impl Finding {
    pub fn new(
        rule: &'static str,
        file: &str,
        line: u32,
        message: String,
    ) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line,
            message,
            waived: None,
            symbol: None,
        }
    }

    pub fn with_symbol(mut self, symbol: Option<String>) -> Finding {
        self.symbol = symbol;
        self
    }
}

/// A parsed `lint:allow` directive.
#[derive(Clone, Debug)]
pub struct Allow {
    pub rule: String,
    pub reason: String,
    /// The single source line this directive applies to: its own line,
    /// or the next code line when the directive stands alone.
    pub line: u32,
    /// The line the directive itself is written on (for diagnostics).
    pub at: u32,
    pub used: bool,
}

/// One lexed source file plus the derived facts every rule consumes.
pub struct SourceFile {
    /// Repo-relative path, `/`-separated.
    pub path: String,
    pub hardened: bool,
    pub tokens: Vec<lexer::Token>,
    /// Per-token: inside a `#[cfg(test)]` or `#[cfg(debug_assertions)]`
    /// item (rules skip these).
    pub exempt: Vec<bool>,
    pub allows: Vec<Allow>,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let lexed = lexer::lex(src);
        let exempt = exempt_regions(&lexed.tokens);
        let allows = parse_allows(&lexed);
        SourceFile {
            path: path.to_string(),
            hardened: is_hardened(path),
            tokens: lexed.tokens,
            exempt,
            allows,
        }
    }
}

/// Mark every token inside a `#[cfg(test)]` / `#[cfg(debug_assertions)]`
/// item. The region runs from the attribute to the item's matching
/// closing brace — or only to a `;` met first (braceless items such as
/// `#[cfg(test)] use ...;`).
fn exempt_regions(tokens: &[lexer::Token]) -> Vec<bool> {
    fn is_cfg_exempt(tokens: &[lexer::Token], i: usize) -> bool {
        i + 6 < tokens.len()
            && tokens[i].is_punct('#')
            && tokens[i + 1].is_punct('[')
            && tokens[i + 2].is_ident("cfg")
            && tokens[i + 3].is_punct('(')
            && (tokens[i + 4].is_ident("test")
                || tokens[i + 4].is_ident("debug_assertions"))
            && tokens[i + 5].is_punct(')')
            && tokens[i + 6].is_punct(']')
    }

    let mut exempt = vec![false; tokens.len()];
    let mut i = 0usize;
    while i < tokens.len() {
        if !is_cfg_exempt(tokens, i) {
            i += 1;
            continue;
        }
        let mut j = i + 7;
        // Find the item body's `{`, unless a `;` ends a braceless item
        // first.
        let mut end = None;
        while j < tokens.len() {
            if tokens[j].is_punct('{') {
                break;
            }
            if tokens[j].is_punct(';') {
                end = Some(j);
                break;
            }
            j += 1;
        }
        let end = end.unwrap_or_else(|| {
            let mut depth = 0usize;
            let mut k = j;
            while k < tokens.len() {
                if tokens[k].is_punct('{') {
                    depth += 1;
                } else if tokens[k].is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k += 1;
            }
            k
        });
        let end = end.min(tokens.len().saturating_sub(1));
        for e in exempt.iter_mut().take(end + 1).skip(i) {
            *e = true;
        }
        i = end + 1;
    }
    exempt
}

/// Extract `lint:allow(rule): reason` directives from the comment side
/// channel. Malformed directives surface later as `bad-waiver` findings
/// (rule name `"?"`, empty reason).
fn parse_allows(lexed: &lexer::Lexed) -> Vec<Allow> {
    let mut out = Vec::new();
    for c in &lexed.comments {
        let Some(rest) = c.text.strip_prefix("lint:allow(") else {
            continue;
        };
        let (rule, reason) = match rest.split_once(')') {
            Some((rule, tail)) => {
                let reason = tail
                    .strip_prefix(':')
                    .map(str::trim)
                    .unwrap_or("")
                    .to_string();
                (rule.trim().to_string(), reason)
            }
            None => ("?".to_string(), String::new()),
        };
        let line = if c.own_line {
            // Applies to the next line bearing code.
            lexed
                .tokens
                .iter()
                .map(|t| t.line)
                .find(|&l| l > c.line)
                .unwrap_or(c.line)
        } else {
            c.line
        };
        out.push(Allow { rule, reason, line, at: c.line, used: false });
    }
    out
}

/// The complete lint result.
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn unwaived(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.waived.is_none())
    }

    pub fn unwaived_count(&self) -> usize {
        self.unwaived().count()
    }

    pub fn waived_count(&self) -> usize {
        self.findings.len() - self.unwaived_count()
    }

    /// Machine-readable report (consumed by the CI artifact).
    ///
    /// Each finding carries a stable `id` built from rule, file, and
    /// enclosing symbol — NOT the line number — with a per-key ordinal
    /// to disambiguate repeats. Unrelated edits that only shift lines
    /// leave the IDs unchanged, so CI artifact diffs across PRs show
    /// real churn only.
    pub fn to_json(&self) -> Json {
        let mut ordinals: BTreeMap<String, usize> = BTreeMap::new();
        let findings = self
            .findings
            .iter()
            .map(|f| {
                let key = format!(
                    "{}@{}::{}",
                    f.rule,
                    f.file,
                    f.symbol.as_deref().unwrap_or("-")
                );
                let k = ordinals.entry(key.clone()).or_insert(0);
                *k += 1;
                let mut o = BTreeMap::new();
                o.insert("id".into(), Json::Str(format!("{key}#{k}")));
                o.insert("rule".into(), Json::Str(f.rule.into()));
                o.insert("file".into(), Json::Str(f.file.clone()));
                o.insert("line".into(), Json::Num(f.line as f64));
                o.insert(
                    "symbol".into(),
                    match &f.symbol {
                        Some(s) => Json::Str(s.clone()),
                        None => Json::Null,
                    },
                );
                o.insert("message".into(), Json::Str(f.message.clone()));
                o.insert(
                    "waived".into(),
                    match &f.waived {
                        Some(r) => Json::Str(r.clone()),
                        None => Json::Null,
                    },
                );
                Json::Obj(o)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert(
            "files_scanned".into(),
            Json::Num(self.files_scanned as f64),
        );
        top.insert("findings".into(), Json::Arr(findings));
        let mut counts = BTreeMap::new();
        counts.insert(
            "total".into(),
            Json::Num(self.findings.len() as f64),
        );
        counts.insert(
            "waived".into(),
            Json::Num(self.waived_count() as f64),
        );
        counts.insert(
            "unwaived".into(),
            Json::Num(self.unwaived_count() as f64),
        );
        top.insert("counts".into(), Json::Obj(counts));
        Json::Obj(top)
    }
}

/// Lint configuration. `root` is the repository root (the directory
/// holding `Cargo.toml`); sources under `rust/src/` and `tools/` are
/// scanned.
pub struct LintOptions {
    pub root: PathBuf,
    /// Format-fingerprint manifest; `None` skips the rule.
    pub manifest: Option<PathBuf>,
    /// Waiver-budget ledger; `None` skips budget enforcement.
    pub ledger: Option<PathBuf>,
    /// Blessed lock-order graph; `None` skips the drift check (the
    /// concurrency pass itself always runs).
    pub lock_graph: Option<PathBuf>,
}

impl LintOptions {
    /// The standard layout rooted at `root`.
    pub fn at(root: impl AsRef<Path>) -> LintOptions {
        let root = root.as_ref().to_path_buf();
        LintOptions {
            manifest: Some(root.join("tools/lint/format.fingerprint.json")),
            ledger: Some(root.join("tools/lint/waivers.ledger")),
            lock_graph: Some(root.join("tools/lint/lock.graph.json")),
            root,
        }
    }
}

/// Lint one in-memory source file: run every token rule, then apply
/// waiver directives. Stale/malformed directives become findings.
/// This is the per-file core of [`run`], separated so tests can feed
/// fixture snippets.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let mut sf = SourceFile::parse(path, src);
    let mut findings = Vec::new();
    rules::check_all(&sf, &mut findings);
    annotate_symbols(&sf, &mut findings);
    apply_waivers(&mut sf, &mut findings);
    findings
}

/// Attach the innermost enclosing `fn` name to every finding of `sf`
/// that doesn't carry one yet (line-level findings only).
fn annotate_symbols(sf: &SourceFile, findings: &mut [Finding]) {
    let spans = concurrency::fn_spans(sf);
    for f in findings.iter_mut() {
        if f.symbol.is_some() || f.line == 0 || f.file != sf.path {
            continue;
        }
        let mut best: Option<&(String, u32, u32)> = None;
        for s in &spans {
            if s.1 <= f.line && f.line <= s.2 {
                // Innermost wins: later/greater start line is deeper.
                if best.map(|b| s.1 >= b.1).unwrap_or(true) {
                    best = Some(s);
                }
            }
        }
        f.symbol = best.map(|s| s.0.clone());
    }
}

fn apply_waivers(sf: &mut SourceFile, findings: &mut Vec<Finding>) {
    for f in findings.iter_mut() {
        if f.waived.is_some() || f.file != sf.path {
            continue;
        }
        if let Some(a) = sf
            .allows
            .iter_mut()
            .find(|a| a.line == f.line && a.rule == f.rule)
        {
            f.waived = Some(a.reason.clone());
            a.used = true;
        }
    }
    for a in &sf.allows {
        if !RULES.contains(&a.rule.as_str()) || a.reason.is_empty() {
            findings.push(Finding::new(
                "bad-waiver",
                &sf.path,
                a.at,
                format!(
                    "malformed waiver: rule {:?}, reason {:?} — use \
                     `lint:allow(<rule>): <reason>` with a known rule \
                     and a non-empty reason",
                    a.rule, a.reason
                ),
            ));
        } else if !a.used {
            findings.push(Finding::new(
                "stale-waiver",
                &sf.path,
                a.at,
                format!(
                    "waiver for {:?} matches no finding — delete it \
                     (and shrink the ledger budget)",
                    a.rule
                ),
            ));
        }
    }
}

/// Recursively collect `.rs` files, sorted for deterministic output.
fn collect_sources(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("scanning {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_sources(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Parse every `.rs` source under `rust/src/` and `tools/` into
/// [`SourceFile`]s (repo-relative `/`-separated paths).
fn parse_sources(root: &Path) -> Result<Vec<SourceFile>> {
    let mut files = Vec::new();
    for sub in ["rust/src", "tools"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_sources(&dir, &mut files)?;
        }
    }
    let mut sources = Vec::with_capacity(files.len());
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        sources.push(SourceFile::parse(&rel, &src));
    }
    Ok(sources)
}

/// Run the full lint over the repository at `opts.root`: per-file
/// rules, then the crate-wide concurrency pass, then the manifest
/// checks and waiver/budget accounting.
pub fn run(opts: &LintOptions) -> Result<Report> {
    let mut sources = parse_sources(&opts.root)?;
    let mut findings = Vec::new();
    for sf in &sources {
        rules::check_all(sf, &mut findings);
    }
    let graph = concurrency::analyze(&sources, &mut findings);
    if let Some(lock_graph) = &opts.lock_graph {
        concurrency::check_graph(lock_graph, &graph, &mut findings)?;
    }
    for sf in &sources {
        annotate_symbols(sf, &mut findings);
    }
    for sf in sources.iter_mut() {
        apply_waivers(sf, &mut findings);
    }
    if let Some(manifest) = &opts.manifest {
        fingerprint::check(&opts.root, manifest, &mut findings)?;
    }
    if let Some(ledger) = &opts.ledger {
        waivers::check(ledger, &mut findings)?;
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule)
            .cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(Report { findings, files_scanned: sources.len() })
}

/// Recompute the crate's lock-order graph and write it as the blessed
/// manifest (the `--bless` path). Findings from the analysis itself are
/// discarded here — `run` reports them; blessing only records the
/// observed graph.
pub fn bless_lock_graph(opts: &LintOptions) -> Result<String> {
    let sources = parse_sources(&opts.root)?;
    let mut sink = Vec::new();
    let graph = concurrency::analyze(&sources, &mut sink);
    let manifest = opts.lock_graph.clone().unwrap_or_else(|| {
        opts.root.join("tools/lint/lock.graph.json")
    });
    concurrency::write_graph(&manifest, &graph)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hardened_zone_matching() {
        assert!(is_hardened("rust/src/adios/wire.rs"));
        assert!(is_hardened("rust/src/adios/sst/writer.rs"));
        assert!(is_hardened("rust/src/pipeline/fleet.rs"));
        assert!(!is_hardened("rust/src/adios/engine.rs"));
        assert!(!is_hardened("rust/src/adios/sstx.rs"));
        assert!(!is_hardened("tools/pallas_lint.rs"));
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let sf = SourceFile::parse(
            "rust/src/adios/wire.rs",
            "fn a() { x.unwrap(); }\n\
             #[cfg(test)]\nmod tests { fn b() { y.unwrap(); } }\n",
        );
        // Tokens of the test mod are exempt; fn a's are not.
        let unwraps: Vec<bool> = sf
            .tokens
            .iter()
            .zip(&sf.exempt)
            .filter(|(t, _)| t.is_ident("unwrap"))
            .map(|(_, &e)| e)
            .collect();
        assert_eq!(unwraps, vec![false, true]);
    }

    #[test]
    fn braceless_cfg_item_ends_at_semicolon() {
        let sf = SourceFile::parse(
            "rust/src/adios/wire.rs",
            "#[cfg(test)]\nuse foo::bar;\nfn a() { x.unwrap(); }\n",
        );
        let unwrap_exempt = sf
            .tokens
            .iter()
            .zip(&sf.exempt)
            .find(|(t, _)| t.is_ident("unwrap"))
            .map(|(_, &e)| e);
        assert_eq!(unwrap_exempt, Some(false));
    }

    #[test]
    fn waiver_on_same_line_suppresses() {
        let f = lint_source(
            "rust/src/adios/wire.rs",
            "fn a() { x.unwrap(); \
             // lint:allow(panic-site): startup only\n}\n",
        );
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "panic-site");
        assert_eq!(f[0].waived.as_deref(), Some("startup only"));
    }

    #[test]
    fn own_line_waiver_covers_next_line() {
        let f = lint_source(
            "rust/src/adios/wire.rs",
            "fn a() {\n    // lint:allow(panic-site): startup only\n    \
             x.unwrap();\n}\n",
        );
        assert_eq!(f.len(), 1);
        assert!(f[0].waived.is_some());
    }

    #[test]
    fn stale_waiver_is_a_finding() {
        let f = lint_source(
            "rust/src/adios/wire.rs",
            "// lint:allow(panic-site): nothing here\nfn a() {}\n",
        );
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "stale-waiver");
        assert!(f[0].waived.is_none());
    }

    #[test]
    fn malformed_waiver_is_a_finding() {
        let f = lint_source(
            "rust/src/adios/wire.rs",
            "fn a() { x.unwrap(); // lint:allow(panic-site)\n}\n",
        );
        // The unwrap stays unwaived AND the directive is flagged.
        assert_eq!(f.len(), 2);
        assert!(f.iter().any(|x| x.rule == "panic-site"
            && x.waived.is_none()));
        assert!(f.iter().any(|x| x.rule == "bad-waiver"));
        let g = lint_source(
            "rust/src/adios/wire.rs",
            "fn a() { x.unwrap(); // lint:allow(no-such-rule): because\n}\n",
        );
        assert!(g.iter().any(|x| x.rule == "bad-waiver"));
    }

    #[test]
    fn json_report_shape() {
        let r = Report {
            findings: vec![Finding::new(
                "panic-site",
                "rust/src/adios/wire.rs",
                7,
                "x".into(),
            )],
            files_scanned: 3,
        };
        let j = r.to_json();
        assert_eq!(j.get("files_scanned").and_then(|v| v.as_u64()),
                   Some(3));
        assert_eq!(
            j.get("counts")
                .and_then(|c| c.get("unwaived"))
                .and_then(|v| v.as_u64()),
            Some(1)
        );
    }
}
