//! SAXS diffraction analysis (the GAPD role).
//!
//! GAPD (E et al. 2018) computes X-ray/electron diffraction of large
//! atomic systems; coupled to PIConGPU it consumes only particle data
//! (§4.2). This analyzer reproduces its SAXS mode: the kinematic sum
//!
//! ```text
//! I(q) = |Σ_j w_j exp(i q·r_j)|²
//! ```
//!
//! over a polar detector grid in the scattering plane, evaluated by the
//! `saxs` artifact in fixed 4096-atom batches. Amplitudes are complex-
//! additive across batches, so the analyzer accumulates (Re, Im) per
//! batch... which the artifact does not expose — it returns I(q) per
//! batch. GAPD's kinematical mode has the same property per *exposure*:
//! incoherent addition of batch intensities is the standard
//! approximation for macroparticle ensembles (each macroparticle bunch
//! is mutually incoherent). We therefore accumulate intensities, and
//! the oracle fallback does the same, so artifact and fallback agree
//! exactly.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{Exec, Runtime};

/// Batch size baked into the artifact (aot.py SAXS_ATOMS).
pub const BATCH_ATOMS: usize = 4096;
/// Q-vectors baked into the artifact (aot.py SAXS_Q).
pub const N_Q: usize = 512;

/// Accumulating SAXS analyzer for one reader rank.
pub struct SaxsAnalyzer {
    exec: Option<Arc<Exec>>,
    /// [3, N_Q] transposed detector q-grid, row-major.
    q_t: Vec<f32>,
    /// Accumulated intensity per q.
    intensity: Vec<f64>,
    pub atoms_seen: u64,
    pub batches_run: u64,
}

impl SaxsAnalyzer {
    /// Polar (log-radial x azimuthal) detector grid, mirroring
    /// model.py's `make_q_grid`.
    pub fn polar_q_grid(q_max: f32, n_q: usize) -> Vec<f32> {
        let n_r = (n_q / 32).max(1);
        let n_phi = n_q / n_r;
        let mut qx = Vec::with_capacity(n_q);
        let mut qy = Vec::with_capacity(n_q);
        let r_min = q_max / 100.0;
        for i in 0..n_r {
            let r = if n_r == 1 {
                q_max
            } else {
                r_min * (q_max / r_min)
                    .powf(i as f32 / (n_r - 1) as f32)
            };
            for j in 0..n_phi {
                let phi =
                    2.0 * std::f32::consts::PI * j as f32 / n_phi as f32;
                qx.push(r * phi.cos());
                qy.push(r * phi.sin());
            }
        }
        qx.truncate(n_q);
        qy.truncate(n_q);
        while qx.len() < n_q {
            qx.push(0.0);
            qy.push(0.0);
        }
        let mut q_t = Vec::with_capacity(3 * n_q);
        q_t.extend_from_slice(&qx);
        q_t.extend_from_slice(&qy);
        q_t.extend(std::iter::repeat(0.0).take(n_q));
        q_t
    }

    pub fn new(q_max: f32, runtime: Option<&Runtime>) -> Result<Self> {
        let exec = match runtime {
            Some(rt) => Some(rt.get("saxs")?),
            None => None,
        };
        Ok(SaxsAnalyzer {
            exec,
            q_t: Self::polar_q_grid(q_max, N_Q),
            intensity: vec![0.0; N_Q],
            atoms_seen: 0,
            batches_run: 0,
        })
    }

    /// Feed particles: `pos` interleaved [n,3], `w` length n. Batches of
    /// `BATCH_ATOMS`, zero-weight padded (exact — zero weight adds
    /// nothing to the kinematic sum).
    pub fn consume(&mut self, pos: &[f32], w: &[f32]) -> Result<()> {
        assert_eq!(pos.len(), w.len() * 3);
        let n = w.len();
        let mut i = 0;
        while i < n {
            let take = (n - i).min(BATCH_ATOMS);
            match self.exec.clone() {
                Some(exec) => {
                    self.consume_batch_pjrt(
                        &exec,
                        &pos[i * 3..(i + take) * 3],
                        &w[i..i + take],
                    )?;
                }
                None => self.consume_batch_fallback(
                    &pos[i * 3..(i + take) * 3],
                    &w[i..i + take],
                ),
            }
            self.atoms_seen += take as u64;
            self.batches_run += 1;
            i += take;
        }
        Ok(())
    }

    fn consume_batch_pjrt(&mut self, exec: &Exec, pos: &[f32], w: &[f32])
        -> Result<()>
    {
        let take = w.len();
        let mut pos_b = vec![0.0f32; BATCH_ATOMS * 3];
        let mut w_b = vec![0.0f32; BATCH_ATOMS];
        pos_b[..take * 3].copy_from_slice(pos);
        w_b[..take].copy_from_slice(w);
        let out = exec.run_f32(&[&pos_b, &w_b, &self.q_t])?;
        for (acc, v) in self.intensity.iter_mut().zip(&out[0]) {
            *acc += *v as f64;
        }
        Ok(())
    }

    /// Pure-rust oracle (O(N·Q)); identical math, used when artifacts
    /// are absent and by the cross-validation test.
    fn consume_batch_fallback(&mut self, pos: &[f32], w: &[f32]) {
        let n_q = N_Q;
        let (qx, qy, qz) = (
            &self.q_t[..n_q],
            &self.q_t[n_q..2 * n_q],
            &self.q_t[2 * n_q..],
        );
        for qi in 0..n_q {
            let (mut re, mut im) = (0.0f64, 0.0f64);
            for (j, &wj) in w.iter().enumerate() {
                let phase = (pos[j * 3] * qx[qi]
                    + pos[j * 3 + 1] * qy[qi]
                    + pos[j * 3 + 2] * qz[qi]) as f64;
                re += wj as f64 * phase.cos();
                im += wj as f64 * phase.sin();
            }
            self.intensity[qi] += re * re + im * im;
        }
    }

    /// The accumulated scatter pattern.
    pub fn pattern(&self) -> &[f64] {
        &self.intensity
    }

    /// Merge another analyzer's accumulation (parallel readers).
    pub fn merge(&mut self, other: &SaxsAnalyzer) {
        self.absorb_pattern(&other.intensity, other.atoms_seen,
                            other.batches_run);
    }

    /// Merge a raw accumulated pattern (e.g. sent back from a worker
    /// thread/process that cannot move its PJRT handles).
    pub fn absorb_pattern(&mut self, pattern: &[f64], atoms: u64,
                          batches: u64) {
        assert_eq!(pattern.len(), self.intensity.len());
        for (a, b) in self.intensity.iter_mut().zip(pattern) {
            *a += *b;
        }
        self.atoms_seen += atoms;
        self.batches_run += batches;
    }

    /// Write the scatter plot as CSV (qx, qy, |q|, I).
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>)
        -> Result<()>
    {
        let n_q = N_Q;
        let mut out = String::from("qx,qy,q,intensity\n");
        for i in 0..n_q {
            let qx = self.q_t[i];
            let qy = self.q_t[n_q + i];
            let q = (qx * qx + qy * qy).sqrt();
            out.push_str(&format!(
                "{qx:.6},{qy:.6},{q:.6},{:.6e}\n",
                self.intensity[i]
            ));
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_particles(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let pos: Vec<f32> =
            (0..n * 3).map(|_| rng.f32() * 64.0).collect();
        let w: Vec<f32> = (0..n).map(|_| 0.5 + rng.f32()).collect();
        (pos, w)
    }

    #[test]
    fn single_atom_gives_unit_intensity() {
        let mut a = SaxsAnalyzer::new(2.0, None).unwrap();
        a.consume(&[1.0, 2.0, 3.0], &[1.0]).unwrap();
        for &v in a.pattern() {
            assert!((v - 1.0).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn intensity_scales_with_weight_squared() {
        let mut a = SaxsAnalyzer::new(2.0, None).unwrap();
        a.consume(&[0.0, 0.0, 0.0], &[3.0]).unwrap();
        for &v in a.pattern() {
            assert!((v - 9.0).abs() < 1e-5);
        }
    }

    #[test]
    fn batches_are_incoherently_additive() {
        let (pos, w) = random_particles(100, 3);
        let mut whole = SaxsAnalyzer::new(2.0, None).unwrap();
        whole.consume(&pos, &w).unwrap();
        let mut parts = SaxsAnalyzer::new(2.0, None).unwrap();
        parts.consume(&pos[..150], &w[..50]).unwrap();
        parts.consume(&pos[150..], &w[50..]).unwrap();
        // Same atoms split into two *batches*: intensities add
        // incoherently, so totals differ from the coherent whole — but
        // both are valid exposures. Check additivity of the accumulator
        // instead: merge == sequential consume.
        let mut m1 = SaxsAnalyzer::new(2.0, None).unwrap();
        m1.consume(&pos[..150], &w[..50]).unwrap();
        let mut m2 = SaxsAnalyzer::new(2.0, None).unwrap();
        m2.consume(&pos[150..], &w[50..]).unwrap();
        m1.merge(&m2);
        for (a, b) in m1.pattern().iter().zip(parts.pattern()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert_eq!(m1.atoms_seen, whole.atoms_seen);
    }

    #[test]
    fn artifact_matches_fallback() {
        let dir = crate::runtime::Runtime::default_dir();
        if !dir.join("meta.json").exists() {
            return;
        }
        let rt = crate::runtime::Runtime::load(dir).unwrap();
        let (pos, w) = random_particles(500, 9);
        let mut a = SaxsAnalyzer::new(2.0, Some(&rt)).unwrap();
        a.consume(&pos, &w).unwrap();
        let mut b = SaxsAnalyzer::new(2.0, None).unwrap();
        b.consume(&pos, &w).unwrap();
        for (i, (x, y)) in
            a.pattern().iter().zip(b.pattern()).enumerate()
        {
            let tol = 1e-3 * y.abs().max(1.0);
            assert!((x - y).abs() < tol, "q[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn csv_output_well_formed() {
        let path = std::env::temp_dir()
            .join(format!("saxs-{}.csv", std::process::id()));
        let mut a = SaxsAnalyzer::new(2.0, None).unwrap();
        a.consume(&[0.0, 0.0, 0.0], &[1.0]).unwrap();
        a.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("qx,qy,q,intensity\n"));
        assert_eq!(text.lines().count(), N_Q + 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn q_grid_magnitudes_bounded() {
        let q_t = SaxsAnalyzer::polar_q_grid(2.0, N_Q);
        for i in 0..N_Q {
            let r = (q_t[i].powi(2) + q_t[N_Q + i].powi(2)).sqrt();
            assert!(r <= 2.0 + 1e-5);
        }
    }
}
