//! Standard-conformance checking.
//!
//! openPMD is a *standard*, so a reproduction should be able to say
//! whether a series conforms. This validator covers the structural rules
//! that matter for the pipelines in this repo: required series attributes,
//! unit metadata on records, consistent component extents, mesh axis
//! metadata matching dimensionality.

use super::record::{Mesh, ParticleSpecies};
use super::series::{Iteration, Series};

/// A single validation finding.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    /// Hierarchy path the finding refers to.
    pub path: String,
    pub message: String,
    pub severity: Severity,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    /// Violates the standard.
    Error,
    /// Legal but suspicious (e.g. unitSI of 0).
    Warning,
}

/// Validate series-level attributes.
pub fn validate_series(series: &Series) -> Vec<Finding> {
    let mut out = Vec::new();
    for required in ["openPMD", "basePath", "iterationEncoding"] {
        if !series.attributes.contains_key(required) {
            out.push(Finding {
                path: "/".into(),
                message: format!("missing required attribute {required:?}"),
                severity: Severity::Error,
            });
        }
    }
    if let Some(v) = series.attributes.get("openPMD") {
        match v.as_str() {
            Some(s) if s.starts_with("1.") || s.starts_with("2.") => {}
            _ => out.push(Finding {
                path: "/".into(),
                message: format!("unsupported openPMD version {v}"),
                severity: Severity::Error,
            }),
        }
    }
    if let Some(v) = series.attributes.get("basePath") {
        if v.as_str() != Some("/data/%T/") {
            out.push(Finding {
                path: "/".into(),
                message: "basePath must be \"/data/%T/\" (fixed by the standard)"
                    .into(),
                severity: Severity::Error,
            });
        }
    }
    out
}

/// Validate one iteration's structure.
pub fn validate_iteration(index: u64, it: &Iteration) -> Vec<Finding> {
    let mut out = Vec::new();
    let prefix = format!("/data/{index}");
    if it.dt < 0.0 {
        out.push(Finding {
            path: prefix.clone(),
            message: format!("negative dt {}", it.dt),
            severity: Severity::Error,
        });
    }
    for (name, mesh) in &it.meshes {
        out.extend(validate_mesh(&format!("{prefix}/meshes/{name}"), mesh));
    }
    for (name, sp) in &it.particles {
        out.extend(validate_species(
            &format!("{prefix}/particles/{name}"), sp));
    }
    out
}

fn validate_mesh(path: &str, mesh: &Mesh) -> Vec<Finding> {
    let mut out = Vec::new();
    let ndim = mesh
        .record
        .components
        .values()
        .next()
        .map(|c| c.dataset.extent.len());
    if let Some(ndim) = ndim {
        if mesh.axis_labels.len() != ndim {
            out.push(Finding {
                path: path.into(),
                message: format!(
                    "axisLabels has {} entries for {ndim}-D mesh",
                    mesh.axis_labels.len()
                ),
                severity: Severity::Error,
            });
        }
        if mesh.grid_spacing.len() != ndim {
            out.push(Finding {
                path: path.into(),
                message: format!(
                    "gridSpacing has {} entries for {ndim}-D mesh",
                    mesh.grid_spacing.len()
                ),
                severity: Severity::Error,
            });
        }
    }
    out.extend(validate_component_extents(path, &mesh.record.components));
    out
}

fn validate_species(path: &str, sp: &ParticleSpecies) -> Vec<Finding> {
    let mut out = Vec::new();
    // All records of a species must describe the same number of particles.
    let mut sizes: Vec<(String, u64)> = Vec::new();
    for (rname, r) in &sp.records {
        for (cname, c) in &r.components {
            let n: u64 = c.dataset.extent.iter().product();
            sizes.push((format!("{rname}/{cname}"), n));
        }
        out.extend(validate_component_extents(
            &format!("{path}/{rname}"), &r.components));
    }
    if let Some((_, first)) = sizes.first() {
        for (who, n) in &sizes {
            if n != first {
                out.push(Finding {
                    path: format!("{path}/{who}"),
                    message: format!(
                        "record component has {n} particles, species has {first}"
                    ),
                    severity: Severity::Error,
                });
            }
        }
    }
    for (rname, r) in &sp.records {
        for (cname, c) in &r.components {
            if c.unit_si == 0.0 {
                out.push(Finding {
                    path: format!("{path}/{rname}/{cname}"),
                    message: "unitSI is 0 (degenerate unit conversion)".into(),
                    severity: Severity::Warning,
                });
            }
        }
    }
    out
}

fn validate_component_extents(
    path: &str,
    comps: &std::collections::BTreeMap<String,
        super::record::RecordComponent>,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut extents = comps.values().map(|c| &c.dataset.extent);
    if let Some(first) = extents.next() {
        if comps.values().any(|c| &c.dataset.extent != first) {
            out.push(Finding {
                path: path.into(),
                message: "components of one record have differing extents"
                    .into(),
                severity: Severity::Error,
            });
        }
    }
    out
}

/// True if no `Error`-severity findings are present.
pub fn is_conformant(findings: &[Finding]) -> bool {
    findings.iter().all(|f| f.severity != Severity::Error)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::openpmd::record::{Dataset, Record};
    use crate::openpmd::types::{Datatype, UnitDimension};
    use crate::openpmd::Attribute;

    #[test]
    fn fresh_series_is_conformant() {
        let s = Series::new("a", "b");
        let f = validate_series(&s);
        assert!(is_conformant(&f), "{f:?}");
    }

    #[test]
    fn missing_version_is_error() {
        let mut s = Series::new("a", "b");
        s.attributes.remove("openPMD");
        assert!(!is_conformant(&validate_series(&s)));
    }

    #[test]
    fn wrong_base_path_is_error() {
        let mut s = Series::new("a", "b");
        s.attributes
            .insert("basePath".into(), Attribute::Str("/other/".into()));
        assert!(!is_conformant(&validate_series(&s)));
    }

    #[test]
    fn pic_layout_iteration_is_conformant() {
        let mut it = Iteration::new(0.0, 0.05);
        it.particles.insert("e".into(), ParticleSpecies::pic_layout(100));
        assert!(is_conformant(&validate_iteration(0, &it)));
    }

    #[test]
    fn mismatched_species_sizes_flagged() {
        let mut sp = ParticleSpecies::pic_layout(100);
        sp.records.insert(
            "extra".into(),
            Record::scalar(UnitDimension::NONE,
                           Dataset::new(Datatype::F32, vec![5])),
        );
        let mut it = Iteration::new(0.0, 0.05);
        it.particles.insert("e".into(), sp);
        let f = validate_iteration(0, &it);
        assert!(!is_conformant(&f), "{f:?}");
    }

    #[test]
    fn bad_axis_labels_flagged() {
        let ds = Dataset::new(Datatype::F32, vec![8, 8]);
        let rec = Record::vector(UnitDimension::electric_field(),
                                 &["x"], ds);
        let mesh = Mesh::cartesian(rec, &["x"], vec![1.0]); // 1 label, 2-D
        let mut it = Iteration::new(0.0, 0.1);
        it.meshes.insert("E".into(), mesh);
        let f = validate_iteration(0, &it);
        assert!(!is_conformant(&f), "{f:?}");
    }

    #[test]
    fn zero_unit_si_is_warning_not_error() {
        let mut sp = ParticleSpecies::pic_layout(10);
        sp.records
            .get_mut("weighting")
            .unwrap()
            .components
            .values_mut()
            .next()
            .unwrap()
            .unit_si = 0.0;
        let mut it = Iteration::new(0.0, 0.05);
        it.particles.insert("e".into(), sp);
        let f = validate_iteration(0, &it);
        assert!(is_conformant(&f)); // warning only
        assert!(!f.is_empty());
    }
}
