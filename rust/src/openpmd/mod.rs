//! The openPMD data model (S1): scientifically self-describing
//! particle–mesh data, independent of any IO backend.
//!
//! openPMD (the *Open Standard for Particle-Mesh Data*, Huebl et al. 2015)
//! standardizes how simulation output is organized and annotated so that
//! analysis, coupling and visualization codes can interpret data without
//! code-specific knowledge — the paper's *expressiveness* criterion
//! (§2.1). This module implements the hierarchy
//!
//! ```text
//! Series
//! └── Iteration (one per simulation output step; == one engine step)
//!     ├── Mesh*             (n-dim field records, e.g. E, B)
//!     │   └── RecordComponent*   (x, y, z or scalar)
//!     └── ParticleSpecies*  (e.g. electrons)
//!         └── Record*       (position, momentum, weighting, ...)
//!             └── RecordComponent*
//! ```
//!
//! plus standardized attributes (units, axis labels, time metadata) and the
//! chunk table ([`chunk::WrittenChunkInfo`]) that the §3 distribution
//! strategies operate on.
//!
//! The mapping onto a concrete backend goes through [`crate::adios`]: one
//! iteration is one engine *step*; record components become variables named
//! by [`series::var_name`]; attributes are flushed with each step.

pub mod attribute;
pub mod chunk;
pub mod record;
pub mod series;
pub mod types;
pub mod validate;

pub use attribute::Attribute;
pub use chunk::{Chunk, WrittenChunkInfo};
pub use record::{Mesh, ParticleSpecies, Record, RecordComponent};
pub use series::{Iteration, Series, var_name};
pub use types::{Datatype, Extent, Offset, UnitDimension};
