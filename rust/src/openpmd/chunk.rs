//! Chunks: the unit of parallel IO and of the §3 distribution problem.
//!
//! A writer rank contributes one or more n-dimensional sub-blocks of each
//! dataset; ADIOS-style backends keep data organized *as written*, so the
//! set of written chunks — with their origin rank and hostname — is exactly
//! the input to the chunk-distribution strategies.

use super::types::{Extent, Offset};

/// An n-dimensional sub-block of a dataset: `offset .. offset + extent`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Chunk {
    pub offset: Offset,
    pub extent: Extent,
}

impl Chunk {
    pub fn new(offset: impl Into<Offset>, extent: impl Into<Extent>) -> Self {
        let c = Chunk { offset: offset.into(), extent: extent.into() };
        debug_assert_eq!(c.offset.len(), c.extent.len());
        c
    }

    /// Whole-dataset chunk.
    pub fn whole(extent: impl Into<Extent>) -> Self {
        let extent = extent.into();
        Chunk { offset: vec![0; extent.len()], extent }
    }

    pub fn ndim(&self) -> usize {
        self.offset.len()
    }

    /// Number of elements.
    pub fn num_elements(&self) -> u64 {
        self.extent.iter().product()
    }

    /// Exclusive upper corner.
    pub fn end(&self) -> Offset {
        self.offset
            .iter()
            .zip(&self.extent)
            .map(|(o, e)| o + e)
            .collect()
    }

    /// Intersection with another chunk, if non-empty.
    pub fn intersect(&self, other: &Chunk) -> Option<Chunk> {
        debug_assert_eq!(self.ndim(), other.ndim());
        let mut offset = Vec::with_capacity(self.ndim());
        let mut extent = Vec::with_capacity(self.ndim());
        for d in 0..self.ndim() {
            let lo = self.offset[d].max(other.offset[d]);
            let hi = (self.offset[d] + self.extent[d])
                .min(other.offset[d] + other.extent[d]);
            if hi <= lo {
                return None;
            }
            offset.push(lo);
            extent.push(hi - lo);
        }
        Some(Chunk { offset, extent })
    }

    /// Does this chunk fully contain `other`?
    pub fn contains(&self, other: &Chunk) -> bool {
        (0..self.ndim()).all(|d| {
            other.offset[d] >= self.offset[d]
                && other.offset[d] + other.extent[d]
                    <= self.offset[d] + self.extent[d]
        })
    }

    /// Split along dimension `dim` at absolute coordinate `at`
    /// (must lie strictly inside). Returns (lower, upper).
    pub fn split_at(&self, dim: usize, at: u64) -> (Chunk, Chunk) {
        assert!(at > self.offset[dim] && at < self.offset[dim] + self.extent[dim],
                "split coordinate {at} outside chunk interior");
        let mut lo = self.clone();
        let mut hi = self.clone();
        lo.extent[dim] = at - self.offset[dim];
        hi.offset[dim] = at;
        hi.extent[dim] = self.offset[dim] + self.extent[dim] - at;
        (lo, hi)
    }

    /// Slice off a prefix of `n` elements measured in *flattened row-major
    /// elements along the slowest (first) dimension*, i.e. whole hyperplanes.
    /// Used by the binpacking strategy which never cuts inner dimensions.
    /// Returns `None` if `n` does not correspond to a whole number of
    /// hyperplanes or is out of range.
    pub fn split_rows(&self, rows: u64) -> Option<(Chunk, Chunk)> {
        if self.ndim() == 0 || rows == 0 || rows >= self.extent[0] {
            return None;
        }
        Some(self.split_at(0, self.offset[0] + rows))
    }
}

/// A written chunk plus its origin in the compute topology — the
/// information the SST reader side gets from the writer's metadata and
/// feeds to the distribution strategies.
#[derive(Clone, Debug, PartialEq)]
pub struct WrittenChunkInfo {
    pub chunk: Chunk,
    /// Writer MPI-style rank that produced the chunk.
    pub source_rank: usize,
    /// Hostname of the producing rank (topology layer for §3.2's
    /// distribution-by-hostname).
    pub hostname: String,
    /// Bytes this chunk actually occupies at the writer — the staged
    /// (operator-encoded) payload size, announced so cost-aware
    /// distribution strategies can balance the bytes that will really
    /// cross the wire. `None` when the writer does not know (e.g. a
    /// metadata-only probe); strategies then fall back to element
    /// counts.
    pub encoded_bytes: Option<u64>,
    /// Which *source engine* of a multiplexed composition announced the
    /// chunk — the reader-side analog of `source_rank` (which names the
    /// producing writer rank). `None` for a plain single-engine table;
    /// [`crate::adios::multiplex::MultiplexReader`] stamps the child
    /// index when it merges its children's tables, so distribution
    /// strategies and reports see where each merged chunk lives. Not a
    /// written property: it never travels on the wire or in BP
    /// metadata.
    pub source_id: Option<usize>,
}

impl WrittenChunkInfo {
    pub fn new(chunk: Chunk, source_rank: usize, hostname: impl Into<String>)
        -> Self
    {
        WrittenChunkInfo {
            chunk,
            source_rank,
            hostname: hostname.into(),
            encoded_bytes: None,
            source_id: None,
        }
    }

    /// Attach the staged payload size in bytes (builder style).
    pub fn with_encoded_bytes(mut self, bytes: u64) -> Self {
        self.encoded_bytes = Some(bytes);
        self
    }

    /// Attach the multiplex source id (builder style; reader-side only).
    pub fn with_source_id(mut self, id: usize) -> Self {
        self.source_id = Some(id);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intersect_overlapping() {
        let a = Chunk::new(vec![0, 0], vec![10, 10]);
        let b = Chunk::new(vec![5, 5], vec![10, 10]);
        let i = a.intersect(&b).unwrap();
        assert_eq!(i, Chunk::new(vec![5, 5], vec![5, 5]));
    }

    #[test]
    fn intersect_disjoint_is_none() {
        let a = Chunk::new(vec![0], vec![5]);
        let b = Chunk::new(vec![5], vec![5]);
        assert!(a.intersect(&b).is_none());
    }

    #[test]
    fn intersect_is_commutative() {
        let a = Chunk::new(vec![2, 0], vec![8, 4]);
        let b = Chunk::new(vec![0, 1], vec![5, 9]);
        assert_eq!(a.intersect(&b), b.intersect(&a));
    }

    #[test]
    fn contains_and_whole() {
        let w = Chunk::whole(vec![16, 16]);
        let inner = Chunk::new(vec![3, 4], vec![2, 2]);
        assert!(w.contains(&inner));
        assert!(!inner.contains(&w));
        assert!(w.contains(&w));
    }

    #[test]
    fn split_preserves_volume_and_disjointness() {
        let c = Chunk::new(vec![4, 0], vec![10, 6]);
        let (lo, hi) = c.split_at(0, 7);
        assert_eq!(lo.num_elements() + hi.num_elements(), c.num_elements());
        assert!(lo.intersect(&hi).is_none());
        assert_eq!(lo.end()[0], hi.offset[0]);
    }

    #[test]
    fn split_rows_edge_cases() {
        let c = Chunk::new(vec![0, 0], vec![4, 8]);
        assert!(c.split_rows(0).is_none());
        assert!(c.split_rows(4).is_none());
        let (lo, hi) = c.split_rows(1).unwrap();
        assert_eq!(lo.extent, vec![1, 8]);
        assert_eq!(hi.extent, vec![3, 8]);
    }

    #[test]
    #[should_panic]
    fn split_outside_panics() {
        let c = Chunk::new(vec![0], vec![4]);
        c.split_at(0, 4);
    }
}
