//! Core scalar types of the data model.

/// Global dataset extent (size per dimension).
pub type Extent = Vec<u64>;
/// Offset of a chunk within a dataset.
pub type Offset = Vec<u64>;

/// Element datatypes supported by the IO layer.
///
/// The set mirrors what the paper's workloads actually move (f32/f64
/// particle data, integer ids); extending it is additive.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Datatype {
    F32,
    F64,
    I32,
    I64,
    U32,
    U64,
    U8,
}

impl Datatype {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            Datatype::F32 | Datatype::I32 | Datatype::U32 => 4,
            Datatype::F64 | Datatype::I64 | Datatype::U64 => 8,
            Datatype::U8 => 1,
        }
    }

    /// Stable tag used by the wire + file formats.
    pub fn tag(self) -> u8 {
        match self {
            Datatype::F32 => 0,
            Datatype::F64 => 1,
            Datatype::I32 => 2,
            Datatype::I64 => 3,
            Datatype::U32 => 4,
            Datatype::U64 => 5,
            Datatype::U8 => 6,
        }
    }

    pub fn from_tag(tag: u8) -> Option<Datatype> {
        Some(match tag {
            0 => Datatype::F32,
            1 => Datatype::F64,
            2 => Datatype::I32,
            3 => Datatype::I64,
            4 => Datatype::U32,
            5 => Datatype::U64,
            6 => Datatype::U8,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Datatype::F32 => "f32",
            Datatype::F64 => "f64",
            Datatype::I32 => "i32",
            Datatype::I64 => "i64",
            Datatype::U32 => "u32",
            Datatype::U64 => "u64",
            Datatype::U8 => "u8",
        }
    }
}

/// Powers of the seven SI base units: (L, M, T, I, Θ, N, J).
///
/// openPMD attaches `unitDimension` to every record so downstream tools can
/// convert units without domain knowledge — part of the FAIR/self-
/// description story (§2.1 *expressiveness*).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UnitDimension(pub [f64; 7]);

impl UnitDimension {
    pub const NONE: UnitDimension = UnitDimension([0.0; 7]);

    /// Length (metres).
    pub fn length() -> Self {
        UnitDimension([1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0])
    }

    /// Momentum (kg·m/s).
    pub fn momentum() -> Self {
        UnitDimension([1.0, 1.0, -1.0, 0.0, 0.0, 0.0, 0.0])
    }

    /// Electric field (V/m = kg·m·A⁻¹·s⁻³).
    pub fn electric_field() -> Self {
        UnitDimension([1.0, 1.0, -3.0, -1.0, 0.0, 0.0, 0.0])
    }

    /// Magnetic field (T = kg·A⁻¹·s⁻²).
    pub fn magnetic_field() -> Self {
        UnitDimension([0.0, 1.0, -2.0, -1.0, 0.0, 0.0, 0.0])
    }

    /// Multiply two dimensions (add exponents).
    pub fn mul(self, other: UnitDimension) -> UnitDimension {
        let mut out = [0.0; 7];
        for i in 0..7 {
            out[i] = self.0[i] + other.0[i];
        }
        UnitDimension(out)
    }
}

/// Number of elements spanned by an extent.
pub fn num_elements(extent: &[u64]) -> u64 {
    extent.iter().product()
}

/// Byte size of a dense chunk of `extent` elements of `dtype`.
pub fn byte_size(dtype: Datatype, extent: &[u64]) -> u64 {
    num_elements(extent) * dtype.size() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datatype_tags_round_trip() {
        for dt in [Datatype::F32, Datatype::F64, Datatype::I32, Datatype::I64,
                   Datatype::U32, Datatype::U64, Datatype::U8] {
            assert_eq!(Datatype::from_tag(dt.tag()), Some(dt));
        }
        assert_eq!(Datatype::from_tag(99), None);
    }

    #[test]
    fn sizes() {
        assert_eq!(Datatype::F32.size(), 4);
        assert_eq!(Datatype::U64.size(), 8);
        assert_eq!(Datatype::U8.size(), 1);
    }

    #[test]
    fn unit_dimension_algebra() {
        let vel = UnitDimension([1.0, 0.0, -1.0, 0.0, 0.0, 0.0, 0.0]);
        let mass = UnitDimension([0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(mass.mul(vel), UnitDimension::momentum());
    }

    #[test]
    fn extent_math() {
        assert_eq!(num_elements(&[4, 5, 6]), 120);
        assert_eq!(byte_size(Datatype::F64, &[10, 10]), 800);
        assert_eq!(num_elements(&[]), 1); // scalar
    }
}
