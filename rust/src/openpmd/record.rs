//! Records, record components, meshes and particle species.
//!
//! A *record* is one physical quantity (position, E-field, weighting); its
//! *components* are the scalar arrays (x/y/z, or a single scalar
//! component). Meshes are records with grid metadata; particle species
//! group per-particle records.

use std::collections::BTreeMap;

use super::attribute::Attribute;
use super::chunk::Chunk;
use super::types::{byte_size, Datatype, Extent, UnitDimension};
use crate::adios::ops::OpChain;
use crate::adios::Bytes;

/// Name used for the single component of scalar records.
pub const SCALAR: &str = "\u{b}_scalar";

/// Dataset declaration: element type + global extent, plus an optional
/// operator chain (openPMD-api's `Dataset::options` compression knob):
/// the series flush declares the variable with this chain, so every
/// backend transforms the component's payloads transparently.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    pub dtype: Datatype,
    pub extent: Extent,
    pub ops: OpChain,
}

impl Dataset {
    pub fn new(dtype: Datatype, extent: impl Into<Extent>) -> Self {
        Dataset {
            dtype,
            extent: extent.into(),
            ops: OpChain::identity(),
        }
    }

    /// Attach an operator chain (builder style).
    pub fn with_ops(mut self, ops: OpChain) -> Self {
        self.ops = ops;
        self
    }
}

/// One scalar array of a record, plus staged chunk writes.
#[derive(Clone, Debug)]
pub struct RecordComponent {
    pub dataset: Dataset,
    /// Conversion factor to SI — `unitSI` in the standard.
    pub unit_si: f64,
    pub attributes: BTreeMap<String, Attribute>,
    /// Writes staged by `store_chunk`, consumed at flush time.
    pending: Vec<(Chunk, Bytes)>,
}

impl RecordComponent {
    pub fn new(dataset: Dataset) -> Self {
        RecordComponent {
            dataset,
            unit_si: 1.0,
            attributes: BTreeMap::new(),
            pending: Vec::new(),
        }
    }

    pub fn with_unit_si(mut self, unit_si: f64) -> Self {
        self.unit_si = unit_si;
        self
    }

    /// Stage a chunk write. Validates bounds and byte length.
    pub fn store_chunk(&mut self, chunk: Chunk, data: Bytes)
        -> Result<(), String>
    {
        if chunk.ndim() != self.dataset.extent.len() {
            return Err(format!(
                "chunk rank {} != dataset rank {}",
                chunk.ndim(),
                self.dataset.extent.len()
            ));
        }
        for d in 0..chunk.ndim() {
            if chunk.offset[d] + chunk.extent[d] > self.dataset.extent[d] {
                return Err(format!(
                    "chunk {:?}+{:?} exceeds dataset extent {:?} in dim {d}",
                    chunk.offset, chunk.extent, self.dataset.extent
                ));
            }
        }
        let want = byte_size(self.dataset.dtype, &chunk.extent) as usize;
        if data.len() != want {
            return Err(format!(
                "chunk payload is {} bytes, extent {:?} x {} needs {want}",
                data.len(),
                chunk.extent,
                self.dataset.dtype.name()
            ));
        }
        self.pending.push((chunk, data));
        Ok(())
    }

    /// Drain staged writes (called by the series flush).
    pub fn take_pending(&mut self) -> Vec<(Chunk, Bytes)> {
        std::mem::take(&mut self.pending)
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

/// A named physical quantity with one or more components.
#[derive(Clone, Debug)]
pub struct Record {
    pub components: BTreeMap<String, RecordComponent>,
    pub unit_dimension: UnitDimension,
    /// openPMD `timeOffset` (in-step offset for staggered quantities).
    pub time_offset: f64,
}

impl Record {
    pub fn new(unit_dimension: UnitDimension) -> Self {
        Record {
            components: BTreeMap::new(),
            unit_dimension,
            time_offset: 0.0,
        }
    }

    /// Vector record with the given component names and a shared dataset.
    pub fn vector(
        unit_dimension: UnitDimension,
        components: &[&str],
        dataset: Dataset,
    ) -> Self {
        let mut r = Record::new(unit_dimension);
        for c in components {
            r.components
                .insert(c.to_string(), RecordComponent::new(dataset.clone()));
        }
        r
    }

    /// Scalar record (single `SCALAR` component).
    pub fn scalar(unit_dimension: UnitDimension, dataset: Dataset) -> Self {
        let mut r = Record::new(unit_dimension);
        r.components
            .insert(SCALAR.to_string(), RecordComponent::new(dataset));
        r
    }

    pub fn component_mut(&mut self, name: &str)
        -> Option<&mut RecordComponent>
    {
        self.components.get_mut(name)
    }

    pub fn is_scalar(&self) -> bool {
        self.components.len() == 1 && self.components.contains_key(SCALAR)
    }
}

/// Mesh geometry as standardized by openPMD.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Geometry {
    Cartesian,
    Cylindrical,
}

impl Geometry {
    pub fn as_str(self) -> &'static str {
        match self {
            Geometry::Cartesian => "cartesian",
            Geometry::Cylindrical => "cylindrical",
        }
    }

    pub fn parse(s: &str) -> Option<Geometry> {
        match s {
            "cartesian" => Some(Geometry::Cartesian),
            "cylindrical" => Some(Geometry::Cylindrical),
            _ => None,
        }
    }
}

/// A mesh record: field data on a structured grid.
#[derive(Clone, Debug)]
pub struct Mesh {
    pub record: Record,
    pub geometry: Geometry,
    pub axis_labels: Vec<String>,
    pub grid_spacing: Vec<f64>,
    pub grid_global_offset: Vec<f64>,
    pub grid_unit_si: f64,
}

impl Mesh {
    pub fn cartesian(record: Record, axis_labels: &[&str],
                     grid_spacing: Vec<f64>) -> Self {
        let n = axis_labels.len();
        Mesh {
            record,
            geometry: Geometry::Cartesian,
            axis_labels: axis_labels.iter().map(|s| s.to_string()).collect(),
            grid_spacing,
            grid_global_offset: vec![0.0; n],
            grid_unit_si: 1.0,
        }
    }
}

/// A particle species: a named group of per-particle records.
#[derive(Clone, Debug, Default)]
pub struct ParticleSpecies {
    pub records: BTreeMap<String, Record>,
    pub attributes: BTreeMap<String, Attribute>,
}

impl ParticleSpecies {
    pub fn new() -> Self {
        Self::default()
    }

    /// Convenience: the canonical PIC species layout used by the producer —
    /// `position` (x,y,z), `momentum` (x,y,z), scalar `weighting`, all f32
    /// with `n` global particles.
    pub fn pic_layout(n: u64) -> Self {
        Self::pic_layout_with_ops(n, OpChain::identity())
    }

    /// [`ParticleSpecies::pic_layout`] with an operator chain on every
    /// component (the producer's `--operators` path).
    pub fn pic_layout_with_ops(n: u64, ops: OpChain) -> Self {
        let ds = Dataset::new(Datatype::F32, vec![n]).with_ops(ops);
        let mut s = ParticleSpecies::new();
        s.records.insert(
            "position".into(),
            Record::vector(UnitDimension::length(), &["x", "y", "z"],
                           ds.clone()),
        );
        s.records.insert(
            "momentum".into(),
            Record::vector(UnitDimension::momentum(), &["x", "y", "z"],
                           ds.clone()),
        );
        s.records.insert(
            "weighting".into(),
            Record::scalar(UnitDimension::NONE, ds),
        );
        s
    }

    /// Total bytes across all staged component writes.
    pub fn pending_bytes(&self) -> u64 {
        self.records
            .values()
            .flat_map(|r| r.components.values())
            .map(|c| {
                c.pending
                    .iter()
                    .map(|(ch, _)| byte_size(c.dataset.dtype, &ch.extent))
                    .sum::<u64>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn bytes(n: usize) -> Bytes {
        Arc::new(vec![0u8; n])
    }

    #[test]
    fn store_chunk_validates_length() {
        let mut c = RecordComponent::new(
            Dataset::new(Datatype::F32, vec![100]));
        assert!(c.store_chunk(Chunk::new(vec![0], vec![10]),
                              bytes(40)).is_ok());
        assert!(c.store_chunk(Chunk::new(vec![0], vec![10]),
                              bytes(39)).is_err());
    }

    #[test]
    fn store_chunk_validates_bounds_and_rank() {
        let mut c = RecordComponent::new(
            Dataset::new(Datatype::F32, vec![100]));
        assert!(c.store_chunk(Chunk::new(vec![95], vec![10]),
                              bytes(40)).is_err());
        assert!(c.store_chunk(Chunk::new(vec![0, 0], vec![5, 2]),
                              bytes(40)).is_err());
    }

    #[test]
    fn take_pending_drains() {
        let mut c = RecordComponent::new(
            Dataset::new(Datatype::F32, vec![8]));
        c.store_chunk(Chunk::new(vec![0], vec![8]), bytes(32)).unwrap();
        assert_eq!(c.pending_len(), 1);
        assert_eq!(c.take_pending().len(), 1);
        assert_eq!(c.pending_len(), 0);
    }

    #[test]
    fn pic_layout_shape() {
        let s = ParticleSpecies::pic_layout(1000);
        assert_eq!(s.records.len(), 3);
        assert_eq!(s.records["position"].components.len(), 3);
        assert!(s.records["weighting"].is_scalar());
        assert_eq!(
            s.records["momentum"].components["x"].dataset.extent,
            vec![1000]
        );
    }

    #[test]
    fn species_pending_bytes() {
        let mut s = ParticleSpecies::pic_layout(64);
        s.records
            .get_mut("position")
            .unwrap()
            .component_mut("x")
            .unwrap()
            .store_chunk(Chunk::new(vec![0], vec![64]), bytes(256))
            .unwrap();
        assert_eq!(s.pending_bytes(), 256);
    }

    #[test]
    fn geometry_round_trip() {
        assert_eq!(Geometry::parse("cartesian"), Some(Geometry::Cartesian));
        assert_eq!(Geometry::parse("weird"), None);
        assert_eq!(Geometry::Cylindrical.as_str(), "cylindrical");
    }
}
