//! Attributes: typed metadata values attached to the series, iterations,
//! records and components. Self-describing output means the meaning of the
//! raw arrays travels with them — this is the carrier.

use std::fmt;

/// A typed attribute value.
#[derive(Clone, Debug, PartialEq)]
pub enum Attribute {
    Str(String),
    F64(f64),
    I64(i64),
    U64(u64),
    Bool(bool),
    VecF64(Vec<f64>),
    VecU64(Vec<u64>),
    VecStr(Vec<String>),
}

impl Attribute {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Attribute::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Attribute::F64(x) => Some(*x),
            Attribute::I64(x) => Some(*x as f64),
            Attribute::U64(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Attribute::U64(x) => Some(*x),
            Attribute::I64(x) if *x >= 0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Attribute::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_vec_f64(&self) -> Option<&[f64]> {
        match self {
            Attribute::VecF64(v) => Some(v),
            _ => None,
        }
    }

    /// Stable type tag for the wire + file formats.
    pub fn tag(&self) -> u8 {
        match self {
            Attribute::Str(_) => 0,
            Attribute::F64(_) => 1,
            Attribute::I64(_) => 2,
            Attribute::U64(_) => 3,
            Attribute::Bool(_) => 4,
            Attribute::VecF64(_) => 5,
            Attribute::VecU64(_) => 6,
            Attribute::VecStr(_) => 7,
        }
    }

    /// Serialize into `out` (length-prefixed little-endian encoding).
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.push(self.tag());
        match self {
            Attribute::Str(s) => {
                out.extend_from_slice(&(s.len() as u32).to_le_bytes());
                out.extend_from_slice(s.as_bytes());
            }
            Attribute::F64(x) => out.extend_from_slice(&x.to_le_bytes()),
            Attribute::I64(x) => out.extend_from_slice(&x.to_le_bytes()),
            Attribute::U64(x) => out.extend_from_slice(&x.to_le_bytes()),
            Attribute::Bool(b) => out.push(*b as u8),
            Attribute::VecF64(v) => {
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Attribute::VecU64(v) => {
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Attribute::VecStr(v) => {
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for s in v {
                    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
                    out.extend_from_slice(s.as_bytes());
                }
            }
        }
    }

    /// Decode from `buf` starting at `*pos`; advances `*pos`.
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<Attribute, String> {
        fn take<'a>(buf: &'a [u8], pos: &mut usize, n: usize)
            -> Result<&'a [u8], String>
        {
            if *pos + n > buf.len() {
                return Err(format!(
                    "attribute decode overrun at {} + {n} > {}", *pos, buf.len()
                ));
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        }
        fn u32_at(buf: &[u8], pos: &mut usize) -> Result<u32, String> {
            Ok(u32::from_le_bytes(take(buf, pos, 4)?.try_into().unwrap()))
        }
        let tag = take(buf, pos, 1)?[0];
        Ok(match tag {
            0 => {
                let n = u32_at(buf, pos)? as usize;
                let s = take(buf, pos, n)?;
                Attribute::Str(String::from_utf8_lossy(s).into_owned())
            }
            1 => Attribute::F64(f64::from_le_bytes(
                take(buf, pos, 8)?.try_into().unwrap(),
            )),
            2 => Attribute::I64(i64::from_le_bytes(
                take(buf, pos, 8)?.try_into().unwrap(),
            )),
            3 => Attribute::U64(u64::from_le_bytes(
                take(buf, pos, 8)?.try_into().unwrap(),
            )),
            4 => Attribute::Bool(take(buf, pos, 1)?[0] != 0),
            5 => {
                let n = u32_at(buf, pos)? as usize;
                // Bound the pre-allocation by the bytes actually left:
                // a corrupted count is a decode error a few elements
                // in, not a multi-gigabyte allocation up front.
                let mut v =
                    Vec::with_capacity(n.min((buf.len() - *pos) / 8));
                for _ in 0..n {
                    v.push(f64::from_le_bytes(
                        take(buf, pos, 8)?.try_into().unwrap(),
                    ));
                }
                Attribute::VecF64(v)
            }
            6 => {
                let n = u32_at(buf, pos)? as usize;
                let mut v =
                    Vec::with_capacity(n.min((buf.len() - *pos) / 8));
                for _ in 0..n {
                    v.push(u64::from_le_bytes(
                        take(buf, pos, 8)?.try_into().unwrap(),
                    ));
                }
                Attribute::VecU64(v)
            }
            7 => {
                let n = u32_at(buf, pos)? as usize;
                let mut v =
                    Vec::with_capacity(n.min((buf.len() - *pos) / 4));
                for _ in 0..n {
                    let m = u32_at(buf, pos)? as usize;
                    let s = take(buf, pos, m)?;
                    v.push(String::from_utf8_lossy(s).into_owned());
                }
                Attribute::VecStr(v)
            }
            other => return Err(format!("unknown attribute tag {other}")),
        })
    }
}

impl fmt::Display for Attribute {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Attribute::Str(s) => write!(f, "{s:?}"),
            Attribute::F64(x) => write!(f, "{x}"),
            Attribute::I64(x) => write!(f, "{x}"),
            Attribute::U64(x) => write!(f, "{x}"),
            Attribute::Bool(b) => write!(f, "{b}"),
            Attribute::VecF64(v) => write!(f, "{v:?}"),
            Attribute::VecU64(v) => write!(f, "{v:?}"),
            Attribute::VecStr(v) => write!(f, "{v:?}"),
        }
    }
}

impl From<&str> for Attribute {
    fn from(s: &str) -> Self {
        Attribute::Str(s.to_string())
    }
}

impl From<f64> for Attribute {
    fn from(x: f64) -> Self {
        Attribute::F64(x)
    }
}

impl From<u64> for Attribute {
    fn from(x: u64) -> Self {
        Attribute::U64(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(a: Attribute) {
        let mut buf = Vec::new();
        a.encode(&mut buf);
        let mut pos = 0;
        let b = Attribute::decode(&buf, &mut pos).unwrap();
        assert_eq!(a, b);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn all_variants_round_trip() {
        round_trip(Attribute::Str("openPMD".into()));
        round_trip(Attribute::F64(1.5e-18));
        round_trip(Attribute::I64(-42));
        round_trip(Attribute::U64(u64::MAX));
        round_trip(Attribute::Bool(true));
        round_trip(Attribute::VecF64(vec![1.0, 0.0, -1.0]));
        round_trip(Attribute::VecU64(vec![64, 64, 64]));
        round_trip(Attribute::VecStr(vec!["x".into(), "y".into()]));
    }

    #[test]
    fn decode_rejects_truncation() {
        let mut buf = Vec::new();
        Attribute::Str("hello".into()).encode(&mut buf);
        buf.truncate(buf.len() - 2);
        let mut pos = 0;
        assert!(Attribute::decode(&buf, &mut pos).is_err());
    }

    #[test]
    fn numeric_coercions() {
        assert_eq!(Attribute::I64(5).as_f64(), Some(5.0));
        assert_eq!(Attribute::I64(5).as_u64(), Some(5));
        assert_eq!(Attribute::I64(-5).as_u64(), None);
        assert_eq!(Attribute::Str("x".into()).as_f64(), None);
    }
}
