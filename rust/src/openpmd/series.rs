//! The series: root object of the openPMD hierarchy, and the mapping of
//! that hierarchy onto step-oriented engines.
//!
//! One openPMD *iteration* maps to one engine *step* (the streaming-
//! friendly encoding: iterations must be consumable one at a time without
//! random access, because a stream cannot seek). Record components map to
//! variables named by [`var_name`]; all metadata travels as step
//! attributes. A `Series` can be flushed to any [`Engine`] — file, stream
//! or JSON — unchanged, which is exactly the paper's *reusability*
//! property: upgrading a file-based IO routine to streaming is a runtime
//! engine switch.
//!
//! **Flush model** (openPMD-api style): `RecordComponent::store_chunk`
//! only *stages* data in the application object; [`Series::
//! write_iteration`] is the `series.flush()` — it declares every record
//! component once via `define_variable`, enqueues every staged chunk with
//! `put_deferred` (no copies — the `Arc`s are handed through), and ends
//! the step, which performs the whole batch as one exchange. On the SST
//! path a full iteration therefore costs one staging pass and one
//! announce, however many chunks it carries.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::attribute::Attribute;
use super::record::{
    Dataset, Geometry, Mesh, ParticleSpecies, Record, RecordComponent, SCALAR,
};
use super::types::{Datatype, UnitDimension};
use crate::adios::{Engine, StepStatus, VarDecl};

/// One output step of the simulation.
#[derive(Clone, Debug, Default)]
pub struct Iteration {
    pub time: f64,
    pub dt: f64,
    pub time_unit_si: f64,
    pub meshes: BTreeMap<String, Mesh>,
    pub particles: BTreeMap<String, ParticleSpecies>,
}

impl Iteration {
    pub fn new(time: f64, dt: f64) -> Self {
        Iteration { time, dt, time_unit_si: 1.0, ..Default::default() }
    }
}

/// Root object: standard metadata + helpers to move iterations through
/// engines.
#[derive(Clone, Debug)]
pub struct Series {
    pub attributes: BTreeMap<String, Attribute>,
    /// Whether the series-level attributes were already published
    /// (they are sent with the first step only).
    base_flushed: bool,
}

pub const OPENPMD_VERSION: &str = "1.1.0";
pub const BASE_PATH: &str = "/data/%T/";
pub const MESHES_PATH: &str = "meshes/";
pub const PARTICLES_PATH: &str = "particles/";

impl Series {
    pub fn new(author: &str, software: &str) -> Self {
        let mut attributes = BTreeMap::new();
        attributes.insert("openPMD".into(),
                          Attribute::Str(OPENPMD_VERSION.into()));
        attributes.insert("openPMDextension".into(), Attribute::U64(0));
        attributes.insert("basePath".into(), Attribute::Str(BASE_PATH.into()));
        attributes.insert("meshesPath".into(),
                          Attribute::Str(MESHES_PATH.into()));
        attributes.insert("particlesPath".into(),
                          Attribute::Str(PARTICLES_PATH.into()));
        attributes.insert("iterationEncoding".into(),
                          Attribute::Str("variableBased".into()));
        attributes.insert("iterationFormat".into(),
                          Attribute::Str("/data/%T/".into()));
        attributes.insert("author".into(), Attribute::Str(author.into()));
        attributes.insert("software".into(), Attribute::Str(software.into()));
        Series { attributes, base_flushed: false }
    }

    /// Flush one iteration as one engine step — the openPMD-api
    /// `series.flush()`. Consumes the staged chunk writes of every
    /// record component: each component is declared once
    /// (`define_variable`), its staged chunks are enqueued with
    /// `put_deferred`, and `end_step` performs the whole batch.
    ///
    /// Returns the step status: on [`StepStatus::Discarded`] (SST
    /// backpressure) nothing was sent and pending data is dropped —
    /// mirroring ADIOS2, where a discarded step's deferred puts never
    /// happen.
    pub fn write_iteration(
        &mut self,
        engine: &mut dyn Engine,
        index: u64,
        iteration: &mut Iteration,
    ) -> Result<StepStatus> {
        let status = engine.begin_step()?;
        match status {
            StepStatus::Ok => {}
            StepStatus::Discarded => {
                // Drop staged data, producer moves on.
                for mesh in iteration.meshes.values_mut() {
                    for c in mesh.record.components.values_mut() {
                        c.take_pending();
                    }
                }
                for sp in iteration.particles.values_mut() {
                    for r in sp.records.values_mut() {
                        for c in r.components.values_mut() {
                            c.take_pending();
                        }
                    }
                }
                return Ok(status);
            }
            other => bail!("begin_step on writer returned {other:?}"),
        }

        if !self.base_flushed {
            for (k, v) in &self.attributes {
                engine.put_attribute(k, v.clone())?;
            }
            self.base_flushed = true;
        }

        let prefix = format!("/data/{index}");
        engine.put_attribute(&format!("{prefix}/time"),
                             Attribute::F64(iteration.time))?;
        engine.put_attribute(&format!("{prefix}/dt"),
                             Attribute::F64(iteration.dt))?;
        engine.put_attribute(&format!("{prefix}/timeUnitSI"),
                             Attribute::F64(iteration.time_unit_si))?;

        for (mname, mesh) in iteration.meshes.iter_mut() {
            let mpath = format!("{prefix}/meshes/{mname}");
            engine.put_attribute(&format!("{mpath}/geometry"),
                                 Attribute::Str(mesh.geometry.as_str().into()))?;
            engine.put_attribute(&format!("{mpath}/axisLabels"),
                                 Attribute::VecStr(mesh.axis_labels.clone()))?;
            engine.put_attribute(&format!("{mpath}/gridSpacing"),
                                 Attribute::VecF64(mesh.grid_spacing.clone()))?;
            engine.put_attribute(
                &format!("{mpath}/gridGlobalOffset"),
                Attribute::VecF64(mesh.grid_global_offset.clone()))?;
            engine.put_attribute(&format!("{mpath}/gridUnitSI"),
                                 Attribute::F64(mesh.grid_unit_si))?;
            flush_record(engine, &mpath, &mut mesh.record)?;
        }

        for (sname, species) in iteration.particles.iter_mut() {
            let spath = format!("{prefix}/particles/{sname}");
            for (k, v) in &species.attributes {
                engine.put_attribute(&format!("{spath}/{k}"), v.clone())?;
            }
            for (rname, record) in species.records.iter_mut() {
                let rpath = format!("{spath}/{rname}");
                flush_record(engine, &rpath, record)?;
            }
        }

        engine.end_step()?;
        Ok(StepStatus::Ok)
    }

    /// Read the next step from an engine, reconstructing the iteration
    /// structure (metadata + dataset declarations; payloads are loaded
    /// separately via `Engine::get`, after chunk distribution).
    ///
    /// `Ok(None)` means no step is ready / stream ended — inspect
    /// the returned status.
    pub fn read_iteration(
        engine: &mut dyn Engine,
    ) -> Result<(StepStatus, Option<(u64, Iteration)>)> {
        let status = engine.begin_step()?;
        if status != StepStatus::Ok {
            return Ok((status, None));
        }
        let mut index: Option<u64> = None;
        let mut it = Iteration::default();

        // Pass 1: variables -> structure.
        for v in engine.available_variables() {
            let parsed = parse_var_name(&v.name)
                .with_context(|| format!("unparseable variable {}", v.name))?;
            index = Some(parsed.index);
            let ds = Dataset::new(v.dtype, v.shape.clone());
            match parsed.location {
                Location::Mesh { mesh, component } => {
                    let m = it.meshes.entry(mesh).or_insert_with(|| {
                        Mesh::cartesian(Record::new(UnitDimension::NONE),
                                        &[], vec![])
                    });
                    m.record
                        .components
                        .insert(component, RecordComponent::new(ds));
                }
                Location::Particle { species, record, component } => {
                    let sp = it
                        .particles
                        .entry(species)
                        .or_insert_with(ParticleSpecies::new);
                    let r = sp
                        .records
                        .entry(record)
                        .or_insert_with(|| Record::new(UnitDimension::NONE));
                    r.components.insert(component, RecordComponent::new(ds));
                }
            }
        }

        let index = match index {
            Some(i) => i,
            None => bail!("step contains no openPMD variables"),
        };

        // Pass 2: attributes -> metadata.
        let prefix = format!("/data/{index}");
        if let Some(a) = engine.attribute(&format!("{prefix}/time")) {
            it.time = a.as_f64().unwrap_or(0.0);
        }
        if let Some(a) = engine.attribute(&format!("{prefix}/dt")) {
            it.dt = a.as_f64().unwrap_or(0.0);
        }
        if let Some(a) = engine.attribute(&format!("{prefix}/timeUnitSI")) {
            it.time_unit_si = a.as_f64().unwrap_or(1.0);
        }
        for (mname, mesh) in it.meshes.iter_mut() {
            let mpath = format!("{prefix}/meshes/{mname}");
            if let Some(a) = engine.attribute(&format!("{mpath}/geometry")) {
                if let Some(g) = a.as_str().and_then(Geometry::parse) {
                    mesh.geometry = g;
                }
            }
            if let Some(Attribute::VecStr(v)) =
                engine.attribute(&format!("{mpath}/axisLabels"))
            {
                mesh.axis_labels = v;
            }
            if let Some(Attribute::VecF64(v)) =
                engine.attribute(&format!("{mpath}/gridSpacing"))
            {
                mesh.grid_spacing = v;
            }
            for (cname, comp) in mesh.record.components.iter_mut() {
                let cpath = component_path(&mpath, cname);
                if let Some(a) = engine.attribute(&format!("{cpath}/unitSI")) {
                    comp.unit_si = a.as_f64().unwrap_or(1.0);
                }
            }
        }
        for (sname, species) in it.particles.iter_mut() {
            let spath = format!("{prefix}/particles/{sname}");
            for (rname, record) in species.records.iter_mut() {
                let rpath = format!("{spath}/{rname}");
                if let Some(Attribute::VecF64(v)) =
                    engine.attribute(&format!("{rpath}/unitDimension"))
                {
                    if v.len() == 7 {
                        let mut dims = [0.0; 7];
                        dims.copy_from_slice(&v);
                        record.unit_dimension = UnitDimension(dims);
                    }
                }
                for (cname, comp) in record.components.iter_mut() {
                    let cpath = component_path(&rpath, cname);
                    if let Some(a) =
                        engine.attribute(&format!("{cpath}/unitSI"))
                    {
                        comp.unit_si = a.as_f64().unwrap_or(1.0);
                    }
                }
            }
        }

        Ok((StepStatus::Ok, Some((index, it))))
    }
}

fn component_path(record_path: &str, component: &str) -> String {
    if component == SCALAR {
        record_path.to_string()
    } else {
        format!("{record_path}/{component}")
    }
}

fn flush_record(
    engine: &mut dyn Engine,
    rpath: &str,
    record: &mut Record,
) -> Result<()> {
    engine.put_attribute(
        &format!("{rpath}/unitDimension"),
        Attribute::VecF64(record.unit_dimension.0.to_vec()),
    )?;
    engine.put_attribute(&format!("{rpath}/timeOffset"),
                         Attribute::F64(record.time_offset))?;
    for (cname, comp) in record.components.iter_mut() {
        let cpath = component_path(rpath, cname);
        engine.put_attribute(&format!("{cpath}/unitSI"),
                             Attribute::F64(comp.unit_si))?;
        // Two-phase: declare once, enqueue every staged chunk; the
        // caller's end_step performs the whole iteration as one batch.
        // The dataset's operator chain rides on the declaration, so the
        // engine transforms payloads transparently at perform time.
        let decl = VarDecl::new(cpath.clone(), comp.dataset.dtype,
                                comp.dataset.extent.clone())
            .with_ops(comp.dataset.ops.clone());
        let handle = engine.define_variable(&decl)?;
        for (chunk, data) in comp.take_pending() {
            engine.put_deferred(&handle, chunk, data)?;
        }
    }
    Ok(())
}

/// Construct a variable name for a particle record component.
pub fn var_name(
    index: u64,
    species: &str,
    record: &str,
    component: &str,
) -> String {
    component_path(
        &format!("/data/{index}/particles/{species}/{record}"),
        component,
    )
}

/// Construct a variable name for a mesh component.
pub fn mesh_var_name(index: u64, mesh: &str, component: &str) -> String {
    component_path(&format!("/data/{index}/meshes/{mesh}"), component)
}

/// Parsed variable location.
#[derive(Debug, PartialEq)]
pub struct ParsedVar {
    pub index: u64,
    pub location: Location,
}

#[derive(Debug, PartialEq)]
pub enum Location {
    Mesh { mesh: String, component: String },
    Particle { species: String, record: String, component: String },
}

/// Parse `/data/{i}/meshes/E/x`, `/data/{i}/particles/e/position/x`,
/// `/data/{i}/particles/e/weighting` (scalar) etc.
pub fn parse_var_name(name: &str) -> Result<ParsedVar> {
    let parts: Vec<&str> = name.split('/').collect();
    // ["", "data", idx, kind, ...]
    if parts.len() < 5 || !parts[0].is_empty() || parts[1] != "data" {
        bail!("not an openPMD variable path: {name:?}");
    }
    let index: u64 = parts[2]
        .parse()
        .with_context(|| format!("bad iteration index in {name:?}"))?;
    let location = match (parts[3], &parts[4..]) {
        ("meshes", [mesh]) => Location::Mesh {
            mesh: mesh.to_string(),
            component: SCALAR.to_string(),
        },
        ("meshes", [mesh, comp]) => Location::Mesh {
            mesh: mesh.to_string(),
            component: comp.to_string(),
        },
        ("particles", [species, record]) => Location::Particle {
            species: species.to_string(),
            record: record.to_string(),
            component: SCALAR.to_string(),
        },
        ("particles", [species, record, comp]) => Location::Particle {
            species: species.to_string(),
            record: record.to_string(),
            component: comp.to_string(),
        },
        _ => bail!("unrecognized openPMD path shape: {name:?}"),
    };
    Ok(ParsedVar { index, location })
}

/// Expand an openPMD dataset declaration helper: f32 1-D particle dataset.
pub fn particle_dataset(n: u64) -> Dataset {
    Dataset::new(Datatype::F32, vec![n])
}

// ---------------------------------------------------------------------
// Sharded output naming (parallel reader fleets)
// ---------------------------------------------------------------------

/// Output shard name for fleet worker `rank` of `readers`: the shard
/// marker goes before the extension so a family of shards sorts next
/// to its base name (`out.bp` → `out.r2of4.bp`). A single-reader fleet
/// keeps the base name — fleet M=1 writes exactly what the serial pipe
/// writes, same path included.
pub fn shard_path(
    base: impl AsRef<std::path::Path>,
    rank: usize,
    readers: usize,
) -> std::path::PathBuf {
    let base = base.as_ref();
    if readers <= 1 {
        return base.to_path_buf();
    }
    let marker = format!("r{rank}of{readers}");
    match (
        base.file_stem().and_then(|s| s.to_str()),
        base.extension().and_then(|e| e.to_str()),
    ) {
        (Some(stem), Some(ext)) => {
            base.with_file_name(format!("{stem}.{marker}.{ext}"))
        }
        _ => {
            let mut name = base
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or("series")
                .to_string();
            name.push('.');
            name.push_str(&marker);
            base.with_file_name(name)
        }
    }
}

/// Write the merged series index next to a fleet's shards:
/// `<base>.index.json` names every shard (rank order) plus the step
/// count, so downstream tooling reassembles the series without
/// globbing — the openPMD "one logical series, many files" pattern.
///
/// The write is **atomic** (temp file in the same directory, then
/// `rename`): a reassembling reader polling for the index observes
/// either no file or a complete one, never a torn prefix.
pub fn write_shard_index(
    base: impl AsRef<std::path::Path>,
    readers: usize,
    steps: u64,
) -> Result<std::path::PathBuf> {
    use crate::util::json::Json;
    let base = base.as_ref();
    let shard_name = |rank: usize| -> String {
        shard_path(base, rank, readers)
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("shard")
            .to_string()
    };
    let mut doc = std::collections::BTreeMap::new();
    doc.insert(
        "series".to_string(),
        Json::Str(
            base.file_name()
                .and_then(|n| n.to_str())
                .unwrap_or("series")
                .to_string(),
        ),
    );
    doc.insert("readers".to_string(), Json::Num(readers as f64));
    doc.insert("steps".to_string(), Json::Num(steps as f64));
    doc.insert(
        "shards".to_string(),
        Json::Arr((0..readers).map(|r| Json::Str(shard_name(r))).collect()),
    );
    let path = std::path::PathBuf::from(format!(
        "{}.index.json",
        base.display()
    ));
    // Same-directory temp + rename: the rename is atomic on POSIX
    // filesystems, so a concurrent open_shard_family never sees a
    // partial document (fs::write alone leaves a visible torn file
    // between create and the final flush).
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, Json::Obj(doc).to_string_pretty())
        .with_context(|| format!("writing shard index temp {tmp:?}"))?;
    std::fs::rename(&tmp, &path).with_context(|| {
        format!("publishing shard index {path:?} (rename from {tmp:?})")
    })?;
    Ok(path)
}

// ---------------------------------------------------------------------
// Shard-index schema + reassembly (the inverse of the fleet)
// ---------------------------------------------------------------------

/// Parsed `<out>.index.json` document: the shard family one fleet run
/// published, in rank order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardIndex {
    /// Base series file name (`out.bp`).
    pub series: String,
    /// Fleet width M the index declares.
    pub readers: usize,
    /// Steps the fleet forwarded.
    pub steps: u64,
    /// Shard file names in rank order (`out.r<i>ofM.bp`; the bare
    /// series name for M = 1).
    pub shards: Vec<String>,
}

/// Typed shard-index failures, so a reassembling reader can tell a
/// torn/incomplete family apart from a malformed document.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardIndexError {
    /// Document is not the expected JSON schema.
    Malformed(String),
    /// The `shards` list length does not match the declared `readers`.
    CountMismatch { declared: usize, listed: usize },
    /// A shard name's `r<i>ofM` marker names a different family width
    /// than the index declares.
    WidthMismatch { name: String, marker: usize, declared: usize },
    /// Two shard names claim the same rank.
    DuplicateRank { rank: usize },
    /// A shard name carries no parseable rank marker (or an
    /// out-of-range one).
    BadShardName { name: String },
    /// A listed shard does not exist on disk.
    MissingShard { path: std::path::PathBuf },
}

impl std::fmt::Display for ShardIndexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardIndexError::Malformed(why) => {
                write!(f, "malformed shard index: {why}")
            }
            ShardIndexError::CountMismatch { declared, listed } => write!(
                f,
                "shard index declares {declared} reader(s) but lists \
                 {listed} shard(s)"
            ),
            ShardIndexError::WidthMismatch {
                name,
                marker,
                declared,
            } => write!(
                f,
                "shard {name:?} is marked as one of {marker} but the \
                 index declares a family of {declared}"
            ),
            ShardIndexError::DuplicateRank { rank } => {
                write!(f, "shard index lists rank {rank} twice")
            }
            ShardIndexError::BadShardName { name } => write!(
                f,
                "shard {name:?} carries no valid r<i>ofM rank marker"
            ),
            ShardIndexError::MissingShard { path } => {
                write!(f, "shard {} is missing on disk", path.display())
            }
        }
    }
}

impl std::error::Error for ShardIndexError {}

/// Parse the `r<i>ofM` marker out of a shard file name
/// (`out.r2of4.bp` → `(2, 4)`).
fn parse_shard_marker(name: &str) -> Option<(usize, usize)> {
    for piece in name.split('.') {
        if let Some(rest) = piece.strip_prefix('r') {
            if let Some((i, m)) = rest.split_once("of") {
                if let (Ok(i), Ok(m)) = (i.parse(), m.parse()) {
                    return Some((i, m));
                }
            }
        }
    }
    None
}

/// Parse and validate a shard-index document: the declared width must
/// match the shard list, every shard's `r<i>ofM` marker must agree
/// with it, and the ranks must cover `0..M` exactly once (a single
/// shard family keeps the unmarked base name, rank 0).
pub fn parse_shard_index(text: &str)
    -> std::result::Result<ShardIndex, ShardIndexError>
{
    let doc = crate::util::json::parse(text)
        .map_err(ShardIndexError::Malformed)?;
    let series = doc
        .get("series")
        .and_then(|s| s.as_str())
        .ok_or_else(|| {
            ShardIndexError::Malformed("missing \"series\" name".into())
        })?
        .to_string();
    let readers = doc
        .get("readers")
        .and_then(|r| r.as_u64())
        .ok_or_else(|| {
            ShardIndexError::Malformed("missing \"readers\" count".into())
        })? as usize;
    if readers == 0 {
        return Err(ShardIndexError::Malformed(
            "a zero-reader shard family cannot exist".into(),
        ));
    }
    let steps = doc.get("steps").and_then(|s| s.as_u64()).ok_or_else(
        || ShardIndexError::Malformed("missing \"steps\" count".into()),
    )?;
    let shards: Vec<String> = doc
        .get("shards")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| {
            ShardIndexError::Malformed("missing \"shards\" list".into())
        })?
        .iter()
        .map(|s| {
            s.as_str().map(str::to_string).ok_or_else(|| {
                ShardIndexError::Malformed(
                    "non-string shard entry".into(),
                )
            })
        })
        .collect::<std::result::Result<_, _>>()?;
    if shards.len() != readers {
        return Err(ShardIndexError::CountMismatch {
            declared: readers,
            listed: shards.len(),
        });
    }
    // Rank coverage: each name's marker must agree with the declared
    // width and the ranks must be exactly {0, .., M-1}.
    let mut seen = vec![false; readers];
    for name in &shards {
        let rank = match parse_shard_marker(name) {
            Some((i, m)) => {
                if m != readers {
                    return Err(ShardIndexError::WidthMismatch {
                        name: name.clone(),
                        marker: m,
                        declared: readers,
                    });
                }
                if i >= readers {
                    return Err(ShardIndexError::BadShardName {
                        name: name.clone(),
                    });
                }
                i
            }
            // M = 1 keeps the unmarked base name: rank 0.
            None if readers == 1 => 0,
            None => {
                return Err(ShardIndexError::BadShardName {
                    name: name.clone(),
                })
            }
        };
        if seen[rank] {
            return Err(ShardIndexError::DuplicateRank { rank });
        }
        seen[rank] = true;
    }
    Ok(ShardIndex { series, readers, steps, shards })
}

/// Open a fleet's shard family as ONE logical series: parse the
/// `<out>.index.json` the fleet wrote, open every shard (BP file or
/// JSON step directory, resolved next to the index), and hand back a
/// [`crate::adios::multiplex::MultiplexReader`] whose merged stream is
/// byte-identical to the pre-fleet serial pipe's output. Missing
/// shards surface as the typed [`ShardIndexError::MissingShard`].
pub fn open_shard_family(
    index: impl AsRef<std::path::Path>,
) -> Result<crate::adios::multiplex::MultiplexReader> {
    let index = index.as_ref();
    let text = std::fs::read_to_string(index)
        .with_context(|| format!("reading shard index {index:?}"))?;
    let parsed = parse_shard_index(&text)
        .map_err(|e| anyhow::anyhow!("{index:?}: {e}"))?;
    let dir = index.parent().unwrap_or_else(|| std::path::Path::new(""));
    let mut names = Vec::with_capacity(parsed.shards.len());
    let mut children: Vec<Box<dyn Engine>> =
        Vec::with_capacity(parsed.shards.len());
    for name in &parsed.shards {
        let path = dir.join(name);
        if !path.exists() {
            return Err(anyhow::anyhow!(
                "{}",
                ShardIndexError::MissingShard { path }
            ));
        }
        children.push(
            crate::adios::spec::open_series_path(&path)
                .with_context(|| format!("opening shard {name}"))?,
        );
        names.push(name.clone());
    }
    crate::adios::multiplex::MultiplexReader::over_named(names, children)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn var_names_round_trip_through_parser() {
        let n = var_name(5, "e", "position", "x");
        assert_eq!(n, "/data/5/particles/e/position/x");
        let p = parse_var_name(&n).unwrap();
        assert_eq!(p.index, 5);
        assert_eq!(
            p.location,
            Location::Particle {
                species: "e".into(),
                record: "position".into(),
                component: "x".into()
            }
        );
    }

    #[test]
    fn scalar_record_has_short_path() {
        let n = var_name(0, "e", "weighting", SCALAR);
        assert_eq!(n, "/data/0/particles/e/weighting");
        let p = parse_var_name(&n).unwrap();
        assert_eq!(
            p.location,
            Location::Particle {
                species: "e".into(),
                record: "weighting".into(),
                component: SCALAR.into()
            }
        );
    }

    #[test]
    fn mesh_names_parse() {
        let n = mesh_var_name(3, "E", "y");
        let p = parse_var_name(&n).unwrap();
        assert_eq!(p.index, 3);
        assert_eq!(
            p.location,
            Location::Mesh { mesh: "E".into(), component: "y".into() }
        );
    }

    #[test]
    fn junk_paths_rejected() {
        assert!(parse_var_name("/other/5/particles/e/p/x").is_err());
        assert!(parse_var_name("/data/notanum/particles/e/p/x").is_err());
        assert!(parse_var_name("bare").is_err());
        assert!(parse_var_name("/data/1/meshes").is_err());
    }

    #[test]
    fn shard_paths_keep_the_extension_and_sort_together() {
        assert_eq!(
            shard_path("out/run.bp", 2, 4),
            std::path::PathBuf::from("out/run.r2of4.bp")
        );
        // M = 1 is the serial pipe's path, unchanged.
        assert_eq!(
            shard_path("out/run.bp", 0, 1),
            std::path::PathBuf::from("out/run.bp")
        );
        // Extension-less bases still get the marker.
        assert_eq!(
            shard_path("out/run", 1, 2),
            std::path::PathBuf::from("out/run.r1of2")
        );
    }

    #[test]
    fn shard_index_lists_every_shard_in_rank_order() {
        let dir = std::env::temp_dir()
            .join(format!("opmd-shardidx-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("fleet.bp");
        let path = write_shard_index(&base, 3, 7).unwrap();
        let doc = crate::util::json::parse(
            &std::fs::read_to_string(&path).unwrap(),
        )
        .unwrap();
        assert_eq!(doc.get("readers").unwrap().as_u64(), Some(3));
        assert_eq!(doc.get("steps").unwrap().as_u64(), Some(7));
        let shards = doc.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[0].as_str(), Some("fleet.r0of3.bp"));
        assert_eq!(shards[2].as_str(), Some("fleet.r2of3.bp"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_index_write_is_atomic_and_round_trips() {
        let dir = std::env::temp_dir()
            .join(format!("opmd-shardidx-atomic-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("fleet.bp");
        // Overwrite an existing (stale) index: rename replaces it in
        // one step.
        std::fs::write(format!("{}.index.json", base.display()),
                       "{ torn garbage").unwrap();
        let path = write_shard_index(&base, 4, 9).unwrap();
        // No temp file may survive the publish.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name().to_string_lossy().contains(".tmp.")
            })
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let parsed = parse_shard_index(
            &std::fs::read_to_string(&path).unwrap(),
        )
        .unwrap();
        assert_eq!(parsed.series, "fleet.bp");
        assert_eq!(parsed.readers, 4);
        assert_eq!(parsed.steps, 9);
        assert_eq!(
            parsed.shards,
            (0..4)
                .map(|r| format!("fleet.r{r}of4.bp"))
                .collect::<Vec<_>>()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_index_schema_violations_are_typed() {
        // Declared M does not match the shard list.
        let mismatch = r#"{"series": "s.bp", "readers": 3, "steps": 1,
            "shards": ["s.r0of3.bp", "s.r1of3.bp"]}"#;
        assert_eq!(
            parse_shard_index(mismatch).unwrap_err(),
            ShardIndexError::CountMismatch { declared: 3, listed: 2 }
        );
        // Marker width disagrees with the declared family width.
        let width = r#"{"series": "s.bp", "readers": 2, "steps": 1,
            "shards": ["s.r0of2.bp", "s.r1of4.bp"]}"#;
        assert_eq!(
            parse_shard_index(width).unwrap_err(),
            ShardIndexError::WidthMismatch {
                name: "s.r1of4.bp".into(),
                marker: 4,
                declared: 2,
            }
        );
        // Two shards claiming one rank.
        let dup = r#"{"series": "s.bp", "readers": 2, "steps": 1,
            "shards": ["s.r0of2.bp", "s.r0of2.bp"]}"#;
        assert_eq!(
            parse_shard_index(dup).unwrap_err(),
            ShardIndexError::DuplicateRank { rank: 0 }
        );
        // Unmarked names are only legal in an M = 1 family.
        let unmarked = r#"{"series": "s.bp", "readers": 2, "steps": 1,
            "shards": ["s.bp", "s.r1of2.bp"]}"#;
        assert_eq!(
            parse_shard_index(unmarked).unwrap_err(),
            ShardIndexError::BadShardName { name: "s.bp".into() }
        );
        // Malformed documents name the missing piece.
        for bad in ["{", "{}", r#"{"series": "s", "readers": 0,
                     "steps": 1, "shards": []}"#] {
            assert!(matches!(
                parse_shard_index(bad).unwrap_err(),
                ShardIndexError::Malformed(_)
            ));
        }
    }

    #[test]
    fn missing_shards_surface_as_typed_errors() {
        let dir = std::env::temp_dir()
            .join(format!("opmd-shardidx-miss-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("gone.bp");
        let index = write_shard_index(&base, 2, 3).unwrap();
        // No shard file was ever written.
        let err = open_shard_family(&index).unwrap_err();
        assert!(format!("{err}").contains("missing on disk"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn series_has_standard_attributes() {
        let s = Series::new("CASUS", "openpmd-stream 0.1");
        assert_eq!(s.attributes["openPMD"].as_str(), Some("1.1.0"));
        assert_eq!(s.attributes["basePath"].as_str(), Some("/data/%T/"));
        assert_eq!(s.attributes["meshesPath"].as_str(), Some("meshes/"));
    }
}
