//! Minimal, offline subset of `once_cell`: just `sync::Lazy`, built on
//! `std::sync::OnceLock`. Sufficient for the static registries and
//! timestamps this codebase uses.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access. The initializer must be `Fn`
    /// (not `FnOnce`) in this subset; every usage in the codebase passes a
    /// capture-free closure or fn pointer, for which this is equivalent.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init }
        }

        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;

    static N: Lazy<u64> = Lazy::new(|| 41 + 1);

    #[test]
    fn lazy_init_once() {
        assert_eq!(*N, 42);
        assert_eq!(*N, 42);
    }
}
