//! Minimal, offline subset of `libc`: exactly the symbols the TCP
//! transport uses to enlarge kernel socket buffers on Linux.

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type c_void = core::ffi::c_void;
pub type socklen_t = u32;

// Linux values.
pub const SOL_SOCKET: c_int = 1;
pub const SO_SNDBUF: c_int = 7;
pub const SO_RCVBUF: c_int = 8;

extern "C" {
    pub fn setsockopt(
        socket: c_int,
        level: c_int,
        option_name: c_int,
        option_value: *const c_void,
        option_len: socklen_t,
    ) -> c_int;
}
