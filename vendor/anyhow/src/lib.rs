//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! This environment builds without network access, so the handful of
//! `anyhow` features the codebase uses are reimplemented here:
//!
//! * [`Error`]: an opaque error value carrying a context chain.
//! * [`Result<T>`]: alias for `Result<T, Error>`.
//! * [`anyhow!`] / [`bail!`]: construct / return errors from format args
//!   or any `Display` value.
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * `From<E: std::error::Error>` so `?` converts std error types.
//!
//! Formatting matches anyhow's conventions where the codebase relies on
//! them: `{}` prints the outermost message, `{:#}` prints the whole chain
//! joined by `": "`.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a chain of messages, outermost context first.
pub struct Error {
    /// `chain[0]` is the outermost message; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// Iterate over the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, anyhow style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str())
                   .unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str())
               .unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Attach context to errors (and missing `Option` values).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format args or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fail_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::Other, "disk bad");
        Err(e).context("writing file")
    }

    #[test]
    fn chain_formats() {
        let e = fail_io().unwrap_err();
        assert_eq!(format!("{e}"), "writing file");
        assert_eq!(format!("{e:#}"), "writing file: disk bad");
    }

    #[test]
    fn macros_work() {
        let e: Error = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }
}
