"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance (see python/tests/). They are also
used by the L2 model tests to validate end-to-end lowering.

Physics notes
-------------
``saxs_ref``
    Kinematical small-angle X-ray scattering (the SAXS mode of GAPD,
    E et al. 2018): the scattering amplitude at reciprocal-space vector q is
    ``A(q) = sum_j w_j * exp(i q . r_j)`` and the recorded intensity is
    ``I(q) = |A(q)|^2``.  ``w_j`` is the macroparticle weighting (used as a
    constant atomic form factor — GAPD's f_j(q) tables collapse to a constant
    in the SAXS regime).

``boris_ref``
    Non-relativistic Boris particle push (the PIConGPU particle hot loop,
    simplified): half electric kick, magnetic rotation, half electric kick,
    then a position update with periodic wrapping.

``hist_ref``
    Weighted 1-D histogram with uniform bins over [emin, emax) — the
    "filter and bin" analysis stage of the paper's Fig. 2 pipeline.
"""

import jax.numpy as jnp


def saxs_ref(pos, w, q_t):
    """Reference SAXS intensity.

    Args:
      pos:  [N, 3] float32 particle positions.
      w:    [1, N] float32 macroparticle weights (constant form factors).
      q_t:  [3, Q] float32 transposed reciprocal-space vectors.

    Returns:
      [Q] float32 intensities I(q) = Re^2 + Im^2.
    """
    phase = pos @ q_t                      # [N, Q]
    re = w @ jnp.cos(phase)                # [1, Q]
    im = w @ jnp.sin(phase)                # [1, Q]
    return (re * re + im * im)[0]


def boris_ref(pos, mom, e_f, b_f, dt, qm, box):
    """Reference Boris push.

    Args:
      pos:  [N, 3] positions.
      mom:  [N, 3] momenta (mass folded into qm; v = mom for m = 1).
      e_f:  [N, 3] electric field gathered at particle positions.
      b_f:  [N, 3] magnetic field gathered at particle positions.
      dt:   scalar time step (python float, baked at trace time).
      qm:   scalar charge-to-mass ratio.
      box:  [3] periodic box lengths.

    Returns:
      (pos', mom') tuple, same shapes.
    """
    h = 0.5 * qm * dt
    v_minus = mom + h * e_f
    t = h * b_f
    t2 = jnp.sum(t * t, axis=-1, keepdims=True)
    s = 2.0 * t / (1.0 + t2)
    v_prime = v_minus + jnp.cross(v_minus, t)
    v_plus = v_minus + jnp.cross(v_prime, s)
    mom_new = v_plus + h * e_f
    pos_new = pos + dt * mom_new
    pos_new = pos_new - jnp.floor(pos_new / box) * box
    return pos_new, mom_new


def hist_ref(e, w, emin, emax, nbins):
    """Reference weighted histogram with uniform binning.

    Args:
      e:     [1, N] sample values (e.g. particle kinetic energies).
      w:     [1, N] sample weights.
      emin, emax: bin range (python floats, baked at trace time).
      nbins: number of bins (python int).

    Returns:
      [nbins] float32 weighted counts.  Out-of-range samples are clamped
      into the first/last bin (matches the kernel; simpler than dropping
      on TPU and preserves total weight).
    """
    width = (emax - emin) / nbins
    idx = jnp.floor((e - emin) / width).astype(jnp.int32)
    idx = jnp.clip(idx, 0, nbins - 1)                       # [1, N]
    onehot = (idx[0][:, None] == jnp.arange(nbins)[None, :]).astype(e.dtype)
    return (w @ onehot)[0]
