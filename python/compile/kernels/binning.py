"""Pallas kernel: weighted 1-D histogram (the pipeline's "filter and bin").

Figure 2 of the paper sketches an analysis stage that "might filter and bin"
the particle stream.  This kernel implements the binning: a weighted
histogram of per-particle energies with uniform bins.

Hardware adaptation: scatter-add histograms (the CUDA idiom: atomicAdd into
shared-memory bins) do not map onto the TPU.  The MXU formulation instead
builds a one-hot matrix per atom tile and reduces it with a matmul:

    idx[N]        = clip(floor((e - emin) / width))
    onehot[N, B]  = (idx == iota(B))
    hist[1, B]   += w[1, N_tile] @ onehot[N_tile, B]     (MXU)

The atom grid dimension accumulates partial histograms into the single
[1, B] output block, same reduction idiom as the SAXS kernel.  Out-of-range
samples are clamped into the edge bins (preserves total weight; the L2 model
widens the range so physical samples never clamp).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_SAMPLES = 1024


def _hist_kernel(emin, width, nbins, e_ref, w_ref, hist_ref):
    i = pl.program_id(0)
    e = e_ref[...]                                            # [1, TILE]
    idx = jnp.floor((e - emin) / width).astype(jnp.int32)
    idx = jnp.clip(idx, 0, nbins - 1)[0]                      # [TILE]
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, nbins), 1)[0]
    onehot = (idx[:, None] == bins[None, :]).astype(jnp.float32)
    part = jnp.dot(w_ref[...], onehot,
                   preferred_element_type=jnp.float32)        # [1, B]

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = part

    @pl.when(i != 0)
    def _accum():
        hist_ref[...] += part


@functools.partial(jax.jit,
                   static_argnames=("emin", "emax", "nbins", "tile"))
def weighted_histogram(e, w, *, emin, emax, nbins, tile=TILE_SAMPLES):
    """Weighted histogram of ``e`` with ``nbins`` uniform bins.

    Args:
      e, w: [1, N] float32 values and weights; N multiple of ``tile``.
      emin, emax, nbins: bin range/count, baked at lowering time.

    Returns:
      [nbins] float32 weighted counts.
    """
    n = e.shape[1]
    assert n % tile == 0, (n, tile)
    width = (float(emax) - float(emin)) / int(nbins)
    kernel = functools.partial(_hist_kernel, float(emin), width, int(nbins))
    out = pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, nbins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, nbins), jnp.float32),
        interpret=True,
    )(e, w)
    return out[0]
