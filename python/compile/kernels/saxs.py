"""Pallas kernel: kinematical SAXS scattering amplitude (GAPD hot spot).

This is the compute hot path of the paper's second benchmark (Sec. 4.2): the
GAPD diffraction code consumes particle positions streamed from PIConGPU and
computes a small-angle X-ray scattering pattern.

Hardware adaptation (CUDA -> TPU, see DESIGN.md "Hardware adaptation"):
GAPD assigns q-space pixels to CUDA threads and loops over atoms per thread.
On TPU we instead phrase the kinematic sum as matrix products so the MXU
systolic array does the heavy lifting:

    phase[N, Q] = pos[N, 3] @ q_t[3, Q]          (MXU)
    re[1, Q]    = w[1, N] @ cos(phase)           (VPU trig + MXU reduce)
    im[1, Q]    = w[1, N] @ sin(phase)

The kernel tiles atoms (grid dim 1, innermost) and q-vectors (grid dim 0);
each (atom-tile, q-tile) block holds a [TA, TQ] phase tile in VMEM and
accumulates partial re/im sums into the [1, TQ] output block.  The atom grid
dimension performs the accumulation: at atom-tile 0 the output block is
initialised, afterwards it is added to — this is the canonical Pallas
reduction idiom, and it expresses the HBM<->VMEM schedule that the CUDA code
expressed with its thread-block loop.

VMEM budget per block (TA=256, TQ=512, f32): pos 3 KiB + q_t 6 KiB
+ phase/cos/sin 3 x 512 KiB + w 1 KiB + out 2 x 2 KiB ~= 1.6 MiB, comfortably
double-bufferable within 16 MiB VMEM.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which both jax-CPU (tests)
and the rust PJRT client (runtime) execute.  Real-TPU numbers are estimated
in DESIGN.md instead of measured.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  TA is the atom-tile (rows of the phase matrix), TQ the
# q-tile (columns).  Multiples of the (8, 128) f32 TPU tile so a real Mosaic
# lowering would not pad.
TILE_ATOMS = 256
TILE_Q = 512


def _saxs_kernel(pos_ref, w_ref, qt_ref, re_ref, im_ref):
    """One (q-tile, atom-tile) block of the kinematic sum."""
    atom_tile = pl.program_id(1)

    phase = jnp.dot(pos_ref[...], qt_ref[...],
                    preferred_element_type=jnp.float32)      # [TA, TQ]
    w = w_ref[...]                                           # [1, TA]
    re_part = jnp.dot(w, jnp.cos(phase),
                      preferred_element_type=jnp.float32)    # [1, TQ]
    im_part = jnp.dot(w, jnp.sin(phase),
                      preferred_element_type=jnp.float32)

    @pl.when(atom_tile == 0)
    def _init():
        re_ref[...] = re_part
        im_ref[...] = im_part

    @pl.when(atom_tile != 0)
    def _accum():
        re_ref[...] += re_part
        im_ref[...] += im_part


@functools.partial(jax.jit, static_argnames=("tile_atoms", "tile_q"))
def saxs_amplitude(pos, w, q_t, *, tile_atoms=TILE_ATOMS, tile_q=TILE_Q):
    """Scattering amplitude via the Pallas kernel.

    Args:
      pos: [N, 3] positions; N must be a multiple of ``tile_atoms``
           (use :func:`saxs_intensity` for automatic padding).
      w:   [1, N] weights.
      q_t: [3, Q] transposed q-vectors; Q multiple of ``tile_q``.

    Returns:
      (re, im): two [1, Q] arrays with the real/imaginary amplitude parts.
    """
    n, q = pos.shape[0], q_t.shape[1]
    assert n % tile_atoms == 0, (n, tile_atoms)
    assert q % tile_q == 0, (q, tile_q)
    grid = (q // tile_q, n // tile_atoms)  # atom tile innermost => reduction

    return pl.pallas_call(
        _saxs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_atoms, 3), lambda j, i: (i, 0)),
            pl.BlockSpec((1, tile_atoms), lambda j, i: (0, i)),
            pl.BlockSpec((3, tile_q), lambda j, i: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_q), lambda j, i: (0, j)),
            pl.BlockSpec((1, tile_q), lambda j, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, q), jnp.float32),
            jax.ShapeDtypeStruct((1, q), jnp.float32),
        ],
        interpret=True,
    )(pos, w, q_t)


def saxs_intensity(pos, w, q_t, *, tile_atoms=TILE_ATOMS, tile_q=TILE_Q):
    """I(q) = |A(q)|^2 with automatic padding to tile multiples.

    Padding atoms with weight zero leaves the amplitude unchanged; padded
    q-columns are computed and then sliced away.
    """
    n, q = pos.shape[0], q_t.shape[1]
    n_pad = (-n) % tile_atoms
    q_pad = (-q) % tile_q
    if n_pad:
        pos = jnp.concatenate([pos, jnp.zeros((n_pad, 3), pos.dtype)], axis=0)
        w = jnp.concatenate([w, jnp.zeros((1, n_pad), w.dtype)], axis=1)
    if q_pad:
        q_t = jnp.concatenate([q_t, jnp.zeros((3, q_pad), q_t.dtype)], axis=1)
    re, im = saxs_amplitude(pos, w, q_t, tile_atoms=tile_atoms, tile_q=tile_q)
    intensity = (re * re + im * im)[0]
    return intensity[:q]
