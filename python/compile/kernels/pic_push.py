"""Pallas kernel: Boris particle push (PIConGPU hot loop, simplified).

The paper's data producer is PIConGPU, a particle-in-cell plasma code.  For
the reproduction the physics fidelity is irrelevant to the IO system — what
matters is that the producer's per-step compute runs through the same
L1 (Pallas) -> L2 (jax) -> artifact -> rust PJRT path as the analysis side,
and that it emits realistically structured particle data.  We therefore
implement the classic (non-relativistic) Boris rotation, the standard PIC
particle pusher, as an element-wise Pallas kernel tiled over particles:

    v-  = p + h*E                 (half electric kick,  h = q dt / 2m)
    t   = h*B ; s = 2t/(1+|t|^2)
    v'  = v- + v- x t             (magnetic rotation)
    v+  = v- + v' x s
    p'  = v+ + h*E                (second half kick)
    x'  = wrap(x + dt * p')       (periodic box)

Each grid step processes a [TILE, 3] tile of particles entirely in VMEM; the
kernel is VPU-bound (no matmul), so the tile is chosen to saturate the
8x128 vector lanes: TILE = 1024 rows of 3 components, padded to 128 lanes by
the layout.  Fields are pre-gathered at particle positions by the L2 model
(bilinear interpolation is a gather — cheap on VPU, awkward in a kernel).

dt / qm / box are *baked* into the artifact at lowering time (python floats
closed over by the traced function): the rust coordinator selects the
artifact, it never feeds scalars on the hot path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_PARTICLES = 1024


def _cross(a, b):
    """Cross product over the last axis written with static slices.

    jnp.cross works in interpret mode too, but spelling it out keeps every
    intermediate a [TILE, 1] column — friendlier to the Mosaic layout pass
    when this kernel is compiled for a real TPU.
    """
    ax, ay, az = a[:, 0:1], a[:, 1:2], a[:, 2:3]
    bx, by, bz = b[:, 0:1], b[:, 1:2], b[:, 2:3]
    return jnp.concatenate(
        [ay * bz - az * by, az * bx - ax * bz, ax * by - ay * bx], axis=1)


def _boris_kernel(dt, qm, box, pos_ref, mom_ref, e_ref, b_ref,
                  pos_out_ref, mom_out_ref):
    h = 0.5 * qm * dt
    e_f = e_ref[...]
    v_minus = mom_ref[...] + h * e_f
    t = h * b_ref[...]
    t2 = jnp.sum(t * t, axis=1, keepdims=True)
    s = (2.0 / (1.0 + t2)) * t
    v_prime = v_minus + _cross(v_minus, t)
    v_plus = v_minus + _cross(v_prime, s)
    mom_new = v_plus + h * e_f
    pos_new = pos_ref[...] + dt * mom_new
    # Periodic wrap, one column at a time: box lengths are python floats
    # baked at trace time (a captured jnp constant would be rejected by
    # pallas_call's closure check).
    cols = [pos_new[:, k:k + 1] - jnp.floor(pos_new[:, k:k + 1] / box[k])
            * box[k] for k in range(3)]
    pos_out_ref[...] = jnp.concatenate(cols, axis=1)
    mom_out_ref[...] = mom_new


@functools.partial(jax.jit,
                   static_argnames=("dt", "qm", "box", "tile"))
def boris_push(pos, mom, e_f, b_f, *, dt, qm, box, tile=TILE_PARTICLES):
    """Push particles one step.

    Args:
      pos, mom, e_f, b_f: [N, 3] float32; N must be a multiple of ``tile``.
      dt, qm: python floats, baked into the lowered HLO.
      box: 3-tuple of python floats (periodic box lengths).

    Returns:
      (pos', mom') [N, 3] float32.
    """
    n = pos.shape[0]
    assert n % tile == 0, (n, tile)
    box_f = tuple(float(b) for b in box)
    kernel = functools.partial(_boris_kernel, float(dt), float(qm), box_f)
    spec = pl.BlockSpec((tile, 3), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, 3), jnp.float32),
            jax.ShapeDtypeStruct((n, 3), jnp.float32),
        ],
        interpret=True,
    )(pos, mom, e_f, b_f)
