"""AOT lowering: jax/pallas -> HLO *text* artifacts for the rust runtime.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each entry point is lowered at the fixed shapes below; the rust runtime pads
its batches to these shapes (weight-0 padding is exact for every entry
point, see runtime/mod.rs).  A sidecar `meta.json` records the shapes so the
coordinator can validate them at load time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed AOT shapes.  Multiples of the kernel tiles (256/512/1024).
SAXS_ATOMS = 4096
SAXS_Q = 512
PIC_PARTICLES = 16384
HIST_SAMPLES = 16384

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def saxs_entry():
    fn = lambda pos, w, q_t: (model.saxs_pattern(pos, w, q_t),)
    args = (
        jax.ShapeDtypeStruct((SAXS_ATOMS, 3), F32),
        jax.ShapeDtypeStruct((1, SAXS_ATOMS), F32),
        jax.ShapeDtypeStruct((3, SAXS_Q), F32),
    )
    meta = {
        "inputs": [list(a.shape) for a in args],
        "outputs": [[SAXS_Q]],
        "doc": "SAXS intensity I(q); inputs pos[N,3], w[1,N], q_t[3,Q]",
    }
    return fn, args, meta


def pic_step_entry():
    fn = lambda pos, mom, e, b: model.pic_step(pos, mom, e, b)
    g = model.GRID
    args = (
        jax.ShapeDtypeStruct((PIC_PARTICLES, 3), F32),
        jax.ShapeDtypeStruct((PIC_PARTICLES, 3), F32),
        jax.ShapeDtypeStruct((g, g, 3), F32),
        jax.ShapeDtypeStruct((g, g, 3), F32),
    )
    meta = {
        "inputs": [list(a.shape) for a in args],
        "outputs": [[PIC_PARTICLES, 3], [PIC_PARTICLES, 3]],
        "doc": "PIC step; inputs pos, mom [N,3], e_grid, b_grid [G,G,3]",
        "constants": {"dt": model.DT, "qm": model.QM, "box": list(model.BOX)},
    }
    return fn, args, meta


def binning_entry():
    fn = lambda mom, w: (model.energy_spectrum(mom, w),)
    args = (
        jax.ShapeDtypeStruct((HIST_SAMPLES, 3), F32),
        jax.ShapeDtypeStruct((1, HIST_SAMPLES), F32),
    )
    meta = {
        "inputs": [list(a.shape) for a in args],
        "outputs": [[model.N_BINS]],
        "doc": "energy spectrum; inputs mom[N,3], w[1,N]",
        "constants": {"emin": model.E_MIN, "emax": model.E_MAX,
                      "nbins": model.N_BINS},
    }
    return fn, args, meta


ENTRIES = {
    "saxs": saxs_entry,
    "pic_step": pic_step_entry,
    "binning": binning_entry,
}


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", choices=sorted(ENTRIES), default=None)
    opts = parser.parse_args()
    os.makedirs(opts.out_dir, exist_ok=True)

    meta_all = {}
    for name, entry in sorted(ENTRIES.items()):
        if opts.only and name != opts.only:
            continue
        fn, args, meta = entry()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(opts.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta_all[name] = meta
        print(f"wrote {path} ({len(text)} chars)")

    meta_path = os.path.join(opts.out_dir, "meta.json")
    existing = {}
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            existing = json.load(f)
    existing.update(meta_all)
    with open(meta_path, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
