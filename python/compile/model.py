"""L2 — the jax compute graphs of both pipeline endpoints.

The paper's pipeline is  PIConGPU (producer)  --SST-->  GAPD (consumer).
This module defines the producer's per-step compute (`pic_step`), the
consumer's diffraction compute (`saxs`), and the auxiliary binning analysis
(`energy_spectrum`), each calling its L1 Pallas kernel so that kernel and
surrounding graph lower into a single fused HLO module.

Everything here is build-time only: `aot.py` lowers these functions once to
HLO text under artifacts/, and the rust coordinator executes the artifacts
through PJRT.  No python on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import binning, pic_push, saxs

# ---------------------------------------------------------------------------
# Producer: Kelvin-Helmholtz-flavoured particle-in-cell step
# ---------------------------------------------------------------------------

# Baked simulation constants (see DESIGN.md: scalars are closed over at
# lowering time; the coordinator never feeds scalars on the hot path).
DT = 0.05
QM = -1.0                    # electron-like charge/mass ratio
BOX = (64.0, 64.0, 64.0)     # periodic box, matches GRID cells of size 1.0
GRID = 64                    # field grid is GRID x GRID over the x-y plane


def gather_fields(pos, grid_f, box=BOX):
    """Bilinear, periodic gather of a [G, G, 3] x-y field at positions.

    PIConGPU gathers E/B at particle positions with (higher-order) shape
    functions; bilinear is the order-1 member of that family and exercises
    the same memory pattern.  The z coordinate does not index the field
    (fields are uniform along z) — this keeps the artifact small while
    preserving a genuinely position-dependent force.
    """
    g = grid_f.shape[0]
    u = pos[:, 0] / box[0] * g
    v = pos[:, 1] / box[1] * g
    u0 = jnp.floor(u).astype(jnp.int32)
    v0 = jnp.floor(v).astype(jnp.int32)
    fu = (u - u0)[:, None]
    fv = (v - v0)[:, None]
    u0 = jnp.mod(u0, g)
    v0 = jnp.mod(v0, g)
    u1 = jnp.mod(u0 + 1, g)
    v1 = jnp.mod(v0 + 1, g)
    f00 = grid_f[u0, v0]
    f01 = grid_f[u0, v1]
    f10 = grid_f[u1, v0]
    f11 = grid_f[u1, v1]
    return ((1 - fu) * (1 - fv) * f00 + (1 - fu) * fv * f01
            + fu * (1 - fv) * f10 + fu * fv * f11)


def pic_step(pos, mom, e_grid, b_grid):
    """One particle-in-cell step: gather fields, Boris push, periodic wrap.

    Args:
      pos, mom: [N, 3] float32 particle state.
      e_grid, b_grid: [GRID, GRID, 3] float32 fields on the x-y plane.

    Returns:
      (pos', mom') — [N, 3] float32 each.
    """
    e_f = gather_fields(pos, e_grid)
    b_f = gather_fields(pos, b_grid)
    return pic_push.boris_push(pos, mom, e_f, b_f, dt=DT, qm=QM, box=BOX)


# ---------------------------------------------------------------------------
# Consumer: GAPD-style kinematical SAXS pattern
# ---------------------------------------------------------------------------

def saxs_pattern(pos, w, q_t):
    """SAXS intensity I(q) for pre-padded shapes (AOT entry point).

    Args:
      pos: [N, 3] positions, N a multiple of the atom tile.
      w:   [1, N] weights.
      q_t: [3, Q] transposed q-vectors, Q a multiple of the q tile.

    Returns:
      [Q] float32 intensity.
    """
    re, im = saxs.saxs_amplitude(pos, w, q_t)
    return (re * re + im * im)[0]


def make_q_grid(q_max, n_q):
    """A polar q-space detector grid in the x-y scattering plane.

    GAPD supports arbitrary plane detector geometries; for the SAXS
    benchmark a log-radial x azimuthal grid is the conventional choice.
    Returns q_t with shape [3, n_q].
    """
    n_r = max(1, n_q // 32)
    n_phi = n_q // n_r
    r = jnp.geomspace(q_max / 100.0, q_max, n_r)
    phi = jnp.linspace(0.0, 2.0 * jnp.pi, n_phi, endpoint=False)
    qx = (r[:, None] * jnp.cos(phi)[None, :]).reshape(-1)
    qy = (r[:, None] * jnp.sin(phi)[None, :]).reshape(-1)
    qz = jnp.zeros_like(qx)
    return jnp.stack([qx, qy, qz], axis=0)[:, :n_q]


# ---------------------------------------------------------------------------
# Analysis: particle energy spectrum (filter + bin)
# ---------------------------------------------------------------------------

E_MIN = 0.0
E_MAX = 8.0
N_BINS = 256


def energy_spectrum(mom, w):
    """Weighted kinetic-energy histogram of the particle stream.

    Args:
      mom: [N, 3] momenta.
      w:   [1, N] weights.

    Returns:
      [N_BINS] float32 spectrum over [E_MIN, E_MAX).
    """
    e = 0.5 * jnp.sum(mom * mom, axis=1)[None, :]            # [1, N]
    return binning.weighted_histogram(
        e, w, emin=E_MIN, emax=E_MAX, nbins=N_BINS)
