"""AOT lowering smoke tests: the HLO text artifacts are well-formed and the
lowered computations numerically match direct jax execution."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_all_entries_lower_to_hlo_text():
    for name, entry in aot.ENTRIES.items():
        fn, args, meta = entry()
        lowered = jax.jit(fn).lower(*args)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        assert len(meta["inputs"]) == len(args), name


def test_saxs_artifact_shapes_in_meta():
    _, args, meta = aot.ENTRIES["saxs"]()
    assert meta["inputs"] == [[aot.SAXS_ATOMS, 3], [1, aot.SAXS_ATOMS],
                              [3, aot.SAXS_Q]]
    assert meta["outputs"] == [[aot.SAXS_Q]]


def test_main_writes_artifacts(tmp_path=None):
    out = tempfile.mkdtemp()
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", out, "--only", "binning"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    assert os.path.exists(os.path.join(out, "binning.hlo.txt"))
    with open(os.path.join(out, "meta.json")) as f:
        meta = json.load(f)
    assert "binning" in meta


def test_lowered_saxs_matches_eager():
    """The exact artifact computation == eager jax on the same inputs."""
    fn, args, _ = aot.ENTRIES["saxs"]()
    rng = np.random.default_rng(0)
    pos = jnp.asarray(rng.uniform(0, 64, size=args[0].shape), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2, size=args[1].shape), jnp.float32)
    q_t = jnp.asarray(rng.normal(0, 0.3, size=args[2].shape), jnp.float32)
    compiled = jax.jit(fn).lower(*args).compile()
    got = compiled(pos, w, q_t)[0]
    want = model.saxs_pattern(pos, w, q_t)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)


def test_q_grid_well_formed():
    q_t = model.make_q_grid(2.0, 512)
    assert q_t.shape == (3, 512)
    r = jnp.sqrt(jnp.sum(q_t ** 2, axis=0))
    assert float(jnp.max(r)) <= 2.0 + 1e-5
    assert float(jnp.min(r)) > 0.0
