"""SAXS Pallas kernel vs pure-jnp oracle — the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, saxs


def _random_case(rng, n, q, scale=1.0):
    pos = jnp.asarray(rng.uniform(0.0, 64.0, size=(n, 3)), jnp.float32) * scale
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=(1, n)), jnp.float32)
    q_t = jnp.asarray(rng.normal(0.0, 0.3, size=(3, q)), jnp.float32)
    return pos, w, q_t


def test_amplitude_matches_ref_exact_tiles():
    rng = np.random.default_rng(0)
    pos, w, q_t = _random_case(rng, 512, 1024)
    re, im = saxs.saxs_amplitude(pos, w, q_t)
    phase = pos @ q_t
    np.testing.assert_allclose(re, w @ jnp.cos(phase), rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(im, w @ jnp.sin(phase), rtol=2e-4, atol=2e-3)


def test_intensity_matches_ref():
    rng = np.random.default_rng(1)
    pos, w, q_t = _random_case(rng, 512, 512)
    got = saxs.saxs_intensity(pos, w, q_t)
    want = ref.saxs_ref(pos, w, q_t)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-1)


def test_intensity_padding_is_exact():
    """Ragged N/Q must give identical results to an un-tiled reference."""
    rng = np.random.default_rng(2)
    pos, w, q_t = _random_case(rng, 300, 77)
    got = saxs.saxs_intensity(pos, w, q_t)
    want = ref.saxs_ref(pos, w, q_t)
    assert got.shape == (77,)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-1)


def test_zero_q_gives_total_weight_squared():
    """I(q=0) = (sum w)^2 — a physics sanity invariant."""
    rng = np.random.default_rng(3)
    pos, w, _ = _random_case(rng, 256, 8)
    q_t = jnp.zeros((3, 8), jnp.float32)
    got = saxs.saxs_intensity(pos, w, q_t)
    total = float(jnp.sum(w)) ** 2
    np.testing.assert_allclose(got, jnp.full((8,), total), rtol=1e-5)


def test_single_atom_unit_intensity():
    """One atom of weight 1 scatters |e^{iq.r}|^2 = 1 at every q."""
    pos = jnp.asarray([[1.0, 2.0, 3.0]], jnp.float32)
    w = jnp.ones((1, 1), jnp.float32)
    q_t = jnp.asarray(np.random.default_rng(4).normal(size=(3, 16)),
                      jnp.float32)
    got = saxs.saxs_intensity(pos, w, q_t)
    np.testing.assert_allclose(got, jnp.ones((16,)), rtol=1e-5, atol=1e-5)


def test_translation_invariance():
    """|A(q)|^2 is invariant under rigid translation of all atoms."""
    rng = np.random.default_rng(5)
    pos, w, q_t = _random_case(rng, 128, 32)
    base = saxs.saxs_intensity(pos, w, q_t)
    shifted = saxs.saxs_intensity(pos + jnp.asarray([1.5, -2.0, 0.25]), w, q_t)
    np.testing.assert_allclose(base, shifted, rtol=5e-3, atol=5e-1)


def test_custom_tiles():
    rng = np.random.default_rng(6)
    pos, w, q_t = _random_case(rng, 256, 256)
    a = saxs.saxs_intensity(pos, w, q_t, tile_atoms=64, tile_q=128)
    b = ref.saxs_ref(pos, w, q_t)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-1)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=400),
    q=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shapes(n, q, seed):
    """Property sweep over ragged shapes: kernel == oracle."""
    rng = np.random.default_rng(seed)
    pos, w, q_t = _random_case(rng, n, q)
    got = saxs.saxs_intensity(pos, w, q_t, tile_atoms=64, tile_q=128)
    want = ref.saxs_ref(pos, w, q_t)
    np.testing.assert_allclose(got, want, rtol=2e-3,
                               atol=1e-3 * max(1.0, float(n)) ** 2)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_dtype_roundtrip(dtype):
    rng = np.random.default_rng(7)
    pos, w, q_t = _random_case(rng, 64, 64)
    got = saxs.saxs_intensity(pos.astype(dtype), w.astype(dtype),
                              q_t.astype(dtype))
    assert got.dtype == jnp.float32
