"""Histogram Pallas kernel vs oracle + conservation properties."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import binning, ref


def _samples(rng, n, lo=0.0, hi=8.0):
    e = jnp.asarray(rng.uniform(lo, hi, size=(1, n)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 3.0, size=(1, n)), jnp.float32)
    return e, w


def test_kernel_matches_ref():
    rng = np.random.default_rng(0)
    e, w = _samples(rng, 4096)
    got = binning.weighted_histogram(e, w, emin=0.0, emax=8.0, nbins=256)
    want = ref.hist_ref(e, w, 0.0, 8.0, 256)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_total_weight_conserved():
    rng = np.random.default_rng(1)
    e, w = _samples(rng, 2048, lo=-5.0, hi=20.0)  # includes out-of-range
    got = binning.weighted_histogram(e, w, emin=0.0, emax=8.0, nbins=64)
    np.testing.assert_allclose(jnp.sum(got), jnp.sum(w), rtol=1e-5)


def test_single_bin_concentration():
    e = jnp.full((1, 1024), 3.0, jnp.float32)
    w = jnp.ones((1, 1024), jnp.float32)
    got = binning.weighted_histogram(e, w, emin=0.0, emax=8.0, nbins=8)
    want = jnp.zeros(8).at[3].set(1024.0)
    np.testing.assert_allclose(got, want)


def test_clamping_edges():
    e = jnp.asarray([[-100.0] * 512 + [100.0] * 512], jnp.float32)
    w = jnp.ones((1, 1024), jnp.float32)
    got = binning.weighted_histogram(e, w, emin=0.0, emax=1.0, nbins=16)
    assert float(got[0]) == 512.0
    assert float(got[15]) == 512.0
    assert float(jnp.sum(got[1:15])) == 0.0


@settings(max_examples=15, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    nbins=st.sampled_from([16, 64, 128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_vs_ref(tiles, nbins, seed):
    rng = np.random.default_rng(seed)
    e, w = _samples(rng, tiles * 1024, lo=-1.0, hi=9.0)
    got = binning.weighted_histogram(e, w, emin=0.0, emax=8.0, nbins=nbins)
    want = ref.hist_ref(e, w, 0.0, 8.0, nbins)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_model_energy_spectrum():
    rng = np.random.default_rng(2)
    mom = jnp.asarray(rng.normal(0, 1, size=(2048, 3)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=(1, 2048)), jnp.float32)
    got = model.energy_spectrum(mom, w)
    assert got.shape == (model.N_BINS,)
    np.testing.assert_allclose(jnp.sum(got), jnp.sum(w), rtol=1e-5)
