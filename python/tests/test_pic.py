"""Boris-push Pallas kernel + L2 pic_step vs oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import pic_push, ref

BOX = (64.0, 64.0, 64.0)


def _state(rng, n):
    pos = jnp.asarray(rng.uniform(0, 64.0, size=(n, 3)), jnp.float32)
    mom = jnp.asarray(rng.normal(0, 1.0, size=(n, 3)), jnp.float32)
    e_f = jnp.asarray(rng.normal(0, 0.1, size=(n, 3)), jnp.float32)
    b_f = jnp.asarray(rng.normal(0, 0.1, size=(n, 3)), jnp.float32)
    return pos, mom, e_f, b_f


def test_kernel_matches_ref():
    rng = np.random.default_rng(0)
    pos, mom, e_f, b_f = _state(rng, 2048)
    got_p, got_m = pic_push.boris_push(pos, mom, e_f, b_f,
                                       dt=0.05, qm=-1.0, box=BOX)
    want_p, want_m = ref.boris_ref(pos, mom, e_f, b_f, 0.05, -1.0,
                                   jnp.asarray(BOX))
    np.testing.assert_allclose(got_m, want_m, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-5)


def test_pure_magnetic_rotation_conserves_energy():
    """With E = 0 the Boris rotation conserves |p| exactly (up to fp)."""
    rng = np.random.default_rng(1)
    pos, mom, _, _ = _state(rng, 1024)
    b_f = jnp.tile(jnp.asarray([[0.0, 0.0, 2.0]], jnp.float32), (1024, 1))
    e_f = jnp.zeros((1024, 3), jnp.float32)
    _, mom2 = pic_push.boris_push(pos, mom, e_f, b_f,
                                  dt=0.1, qm=-1.0, box=BOX)
    np.testing.assert_allclose(
        jnp.sum(mom2 * mom2, axis=1), jnp.sum(mom * mom, axis=1),
        rtol=1e-5)


def test_positions_stay_in_box():
    rng = np.random.default_rng(2)
    pos, mom, e_f, b_f = _state(rng, 1024)
    mom = mom * 100.0  # huge velocities to force wrapping
    pos2, _ = pic_push.boris_push(pos, mom, e_f, b_f,
                                  dt=0.05, qm=-1.0, box=BOX)
    assert bool(jnp.all(pos2 >= 0.0))
    assert bool(jnp.all(pos2 < jnp.asarray(BOX)))


def test_zero_fields_free_streaming():
    rng = np.random.default_rng(3)
    pos, mom, _, _ = _state(rng, 1024)
    z = jnp.zeros((1024, 3), jnp.float32)
    pos2, mom2 = pic_push.boris_push(pos, mom, z, z,
                                     dt=0.05, qm=-1.0, box=BOX)
    np.testing.assert_allclose(mom2, mom, rtol=1e-6)
    want = pos + 0.05 * mom
    want = want - jnp.floor(want / jnp.asarray(BOX)) * jnp.asarray(BOX)
    np.testing.assert_allclose(pos2, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    dt=st.floats(min_value=1e-3, max_value=0.2),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_kernel_vs_ref(tiles, dt, seed):
    rng = np.random.default_rng(seed)
    n = tiles * 1024
    pos, mom, e_f, b_f = _state(rng, n)
    got_p, got_m = pic_push.boris_push(pos, mom, e_f, b_f,
                                       dt=dt, qm=-1.0, box=BOX)
    want_p, want_m = ref.boris_ref(pos, mom, e_f, b_f, dt, -1.0,
                                   jnp.asarray(BOX))
    np.testing.assert_allclose(got_m, want_m, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-4, atol=1e-4)


def test_model_pic_step_shapes_and_wrap():
    rng = np.random.default_rng(4)
    n = 2048
    pos = jnp.asarray(rng.uniform(0, 64.0, size=(n, 3)), jnp.float32)
    mom = jnp.asarray(rng.normal(0, 1, size=(n, 3)), jnp.float32)
    g = model.GRID
    e_grid = jnp.asarray(rng.normal(0, 0.1, size=(g, g, 3)), jnp.float32)
    b_grid = jnp.asarray(rng.normal(0, 0.1, size=(g, g, 3)), jnp.float32)
    pos2, mom2 = model.pic_step(pos, mom, e_grid, b_grid)
    assert pos2.shape == (n, 3) and mom2.shape == (n, 3)
    assert bool(jnp.all(pos2 >= 0)) and bool(jnp.all(pos2 < 64.0))


def test_gather_fields_constant_field():
    """Gathering a constant field returns that constant everywhere."""
    rng = np.random.default_rng(5)
    pos = jnp.asarray(rng.uniform(0, 64.0, size=(256, 3)), jnp.float32)
    g = model.GRID
    const = jnp.tile(jnp.asarray([[1.0, -2.0, 3.0]], jnp.float32),
                     (g * g, 1)).reshape(g, g, 3)
    got = model.gather_fields(pos, const)
    np.testing.assert_allclose(
        got, jnp.tile(jnp.asarray([[1.0, -2.0, 3.0]]), (256, 1)),
        rtol=1e-5, atol=1e-5)
