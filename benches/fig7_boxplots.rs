//! Regenerates **Fig. 7**: perceived runtimes for file-based writes
//! (BP-only) and streaming loads (SST phase of SST+BP) as boxplots —
//! median, quartiles, 1.5·IQR whiskers and outlier counts, pooled over
//! three repetitions (the paper's plotting convention).

use openpmd_stream::bench::fig6::{simulate, Fig6Params, Setup};
use openpmd_stream::bench::{smoke_mode, Table};
use openpmd_stream::pipeline::metrics::OpKind;
use openpmd_stream::util::cli::Args;
use openpmd_stream::util::stats::boxplot;

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "FIG7_SMOKE");
    let nodes_sweep: &[usize] =
        if smoke { &[64] } else { &[64, 128, 256, 512] };
    let reps = if smoke { 1 } else { 3 };

    let mut t = Table::new(
        "Fig 7: write/load time distributions [s] (3 reps pooled)",
        &["nodes", "series", "n", "w-", "q1", "median", "q3", "w+",
          "max", "outliers"],
    );

    for &nodes in nodes_sweep {
        let mut bp_times = Vec::new();
        let mut stream_times = Vec::new();
        for rep in 0..reps {
            let params = Fig6Params {
                nodes,
                seed: 2000 + rep as u64,
                ..Default::default()
            };
            let bp = simulate(Setup::BpOnly, &params);
            bp_times.extend(bp.store_metrics.durations(OpKind::Store));
            let sst = simulate(Setup::SstBp, &params);
            stream_times.extend(sst.load_metrics.durations(OpKind::Load));
        }
        for (label, times) in [("BP-only write", &bp_times),
                               ("SST stream load", &stream_times)] {
            if times.is_empty() {
                continue;
            }
            let b = boxplot(times);
            t.row(vec![
                nodes.to_string(),
                label.into(),
                b.n.to_string(),
                format!("{:.1}", b.lower_whisker),
                format!("{:.1}", b.q1),
                format!("{:.1}", b.median),
                format!("{:.1}", b.q3),
                format!("{:.1}", b.upper_whisker),
                format!("{:.1}", b.max),
                b.outliers.len().to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    t.save_csv("fig7_boxplots").ok();
    println!(
        "\npaper reference: BP-only medians 10-15 s (worst outlier 45 s); \
         streaming medians 5-7 s (worst ~9 s); outliers increase from \
         256 nodes, and at 512 long load times start skewing the median."
    );
}
