//! M2 (ours): measured (not simulated) transport throughput of the real
//! SST engine — inproc (RDMA-analog zero-copy) vs TCP sockets vs BP
//! file — one writer, one reader, aligned whole-chunk reads.
//!
//! This is the measured counterpart of the simulated Fig. 8 transport
//! comparison: the same ordering (zero-copy > sockets; both >> file for
//! re-reading) must show up on real hardware at laptop scale.

use std::time::Duration;

use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
use openpmd_stream::adios::engine::{Engine, StepStatus, VarDecl};
use openpmd_stream::adios::sst::{
    QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions, SstWriter,
    SstWriterOptions,
};
use openpmd_stream::bench::{smoke_mode, Table};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::types::Datatype;
use openpmd_stream::util::bytes::{fmt_bytes, fmt_rate, MIB};
use openpmd_stream::util::cli::Args;

const STEPS: u64 = 12;

/// Stream `STEPS` x `chunk_mib` through an SST pair; return bytes/s as
/// seen by the reader (perceived: request to last byte).
fn sst_throughput(transport: &str, chunk_mib: u64) -> f64 {
    let payload = vec![7u8; (chunk_mib * MIB) as usize];
    let payload = std::sync::Arc::new(payload);
    let mut writer = SstWriter::open(SstWriterOptions {
        listen: if transport == "inproc" {
            format!("bench-{}-{}", chunk_mib, std::process::id())
        } else {
            String::new()
        },
        transport: transport.into(),
        queue: QueueConfig { policy: QueueFullPolicy::Block, limit: 4 },
        ..Default::default()
    })
    .unwrap();
    let addr = writer.address();
    let transport = transport.to_string();
    let n = payload.len() as u64;

    let reader_thread = std::thread::spawn(move || {
        let mut reader = SstReader::open(SstReaderOptions {
            writers: vec![addr],
            transport,
            begin_step_timeout: Duration::from_secs(60),
            ..Default::default()
        })
        .unwrap();
        let mut total = 0u64;
        let t0 = std::time::Instant::now();
        loop {
            match reader.begin_step().unwrap() {
                StepStatus::Ok => {}
                StepStatus::EndOfStream => break,
                _ => continue,
            }
            let data = reader
                .get("/x", Chunk::whole(vec![n]))
                .unwrap();
            total += data.len() as u64;
            reader.end_step().unwrap();
        }
        let secs = t0.elapsed().as_secs_f64();
        let stats = reader.stats();
        // Two-phase batching contract: one wire data message per step —
        // the aligned whole-chunk read of each step travels as exactly
        // one GetBatchReply.
        assert_eq!(stats.data_messages, stats.steps_consumed,
                   "expected one batched payload per step: {stats:?}");
        assert_eq!(stats.batch_requests, stats.steps_consumed,
                   "expected one batched request per step: {stats:?}");
        reader.close().unwrap();
        total as f64 / secs
    });

    let var = VarDecl::new("/x", Datatype::U8, vec![n]);
    for _ in 0..STEPS {
        writer.begin_step().unwrap();
        writer
            .put(&var, Chunk::whole(vec![n]), payload.clone())
            .unwrap();
        writer.end_step().unwrap();
    }
    writer.close().unwrap();
    reader_thread.join().unwrap()
}

/// Write + re-read the same data through the BP file engine.
fn bp_throughput(chunk_mib: u64) -> (f64, f64) {
    let path = std::env::temp_dir()
        .join(format!("bench-bp-{}-{}.bp", chunk_mib, std::process::id()));
    let payload =
        std::sync::Arc::new(vec![7u8; (chunk_mib * MIB) as usize]);
    let n = payload.len() as u64;
    let var = VarDecl::new("/x", Datatype::U8, vec![n]);

    let t0 = std::time::Instant::now();
    let mut w = BpWriter::create(&path, WriterCtx::default()).unwrap();
    for _ in 0..STEPS {
        w.begin_step().unwrap();
        w.put(&var, Chunk::whole(vec![n]), payload.clone()).unwrap();
        w.end_step().unwrap();
    }
    w.close().unwrap();
    let write_rate =
        (STEPS * n) as f64 / t0.elapsed().as_secs_f64();

    let t0 = std::time::Instant::now();
    let mut r = BpReader::open(&path).unwrap();
    let mut total = 0u64;
    while r.begin_step().unwrap() == StepStatus::Ok {
        total += r.get("/x", Chunk::whole(vec![n])).unwrap().len() as u64;
        r.end_step().unwrap();
    }
    let read_rate = total as f64 / t0.elapsed().as_secs_f64();
    std::fs::remove_file(&path).ok();
    (write_rate, read_rate)
}

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "MICRO_TRANSPORT_SMOKE");
    let sweep: &[u64] = if smoke { &[1, 16] } else { &[1, 16, 64, 256] };
    let mut t = Table::new(
        "M2: measured single-pair transport throughput (12 steps)",
        &["chunk", "SST inproc (zero-copy)", "SST tcp", "BP write",
          "BP read"],
    );
    for &chunk_mib in sweep {
        let inproc = sst_throughput("inproc", chunk_mib);
        let tcp = sst_throughput("tcp", chunk_mib);
        let (bp_w, bp_r) = bp_throughput(chunk_mib);
        t.row(vec![
            fmt_bytes(chunk_mib * MIB),
            fmt_rate(inproc),
            fmt_rate(tcp),
            fmt_rate(bp_w),
            fmt_rate(bp_r),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("micro_transport").ok();
    println!(
        "\nexpected ordering at large chunks: inproc >> tcp (zero-copy \
         Arc hand-off vs serialize+socket+deserialize) — the measured \
         analog of the paper's RDMA-vs-sockets gap."
    );
}
