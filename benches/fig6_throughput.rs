//! Regenerates **Fig. 6** (perceived total throughput of the §4.1
//! asynchronous-IO pipeline) plus the dump-count and IO-share numbers
//! quoted in the §4.1 text (experiments F6, D1, D2 in DESIGN.md).
//!
//! Three series per node count, each repeated 3x (the paper's protocol):
//!   * BP-only            — blocking node-aggregated file writes;
//!   * SST (streaming)    — the stream hand-off phase of SST+BP;
//!   * SST+BP (file)      — the pipe's asynchronous file phase.

use openpmd_stream::bench::fig6::{simulate, Fig6Params, Setup};
use openpmd_stream::bench::{smoke_mode, BenchJson, Table};
use openpmd_stream::pipeline::metrics::OpKind;
use openpmd_stream::util::bytes::fmt_rate;
use openpmd_stream::util::cli::Args;

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "FIG6_SMOKE");
    let nodes_sweep: &[usize] =
        if smoke { &[64] } else { &[64, 128, 256, 512] };
    let reps = if smoke { 1 } else { 3 };

    let mut fig = Table::new(
        "Fig 6: perceived total throughput (3 repetitions each)",
        &["nodes", "setup", "series", "rep", "aggregate rate", "ops"],
    );
    let mut dumps = Table::new(
        "SS 4.1: successfully written dumps in 15 min (paper: BP-only \
         22-23 -> 17-20; SST+BP 32-34 -> 16-17)",
        &["nodes", "BP-only dumps", "SST+BP dumps", "SST+BP discarded"],
    );
    let mut shares = Table::new(
        "SS 4.1: IO share of simulation time (raw% / plugin%) \
         (paper: BP-only 44/54 -> 55/64; SST 2.1/27 -> 6.2/32)",
        &["nodes", "BP-only raw", "BP-only plugin", "SST raw",
          "SST plugin"],
    );

    for &nodes in nodes_sweep {
        let mut bp_dumps = Vec::new();
        let mut sst_dumps = Vec::new();
        let mut sst_disc = Vec::new();
        let mut bp_fracs = (0.0, 0.0);
        let mut sst_fracs = (0.0, 0.0);
        for rep in 0..reps {
            let params = Fig6Params {
                nodes,
                seed: 1000 + rep as u64,
                ..Default::default()
            };
            let bp = simulate(Setup::BpOnly, &params);
            let sst = simulate(Setup::SstBp, &params);

            let bp_rate = bp.store_metrics.report(OpKind::Store, nodes);
            fig.row(vec![
                nodes.to_string(),
                "BP-only".into(),
                "file write".into(),
                rep.to_string(),
                fmt_rate(bp_rate.aggregate_rate),
                bp_rate.ops.to_string(),
            ]);
            let stream =
                sst.load_metrics.report(OpKind::Load, nodes * 6);
            fig.row(vec![
                nodes.to_string(),
                "SST+BP".into(),
                "SST stream".into(),
                rep.to_string(),
                fmt_rate(stream.aggregate_rate),
                stream.ops.to_string(),
            ]);
            let file = sst.file_metrics.report(OpKind::Store, nodes);
            fig.row(vec![
                nodes.to_string(),
                "SST+BP".into(),
                "BP file phase".into(),
                rep.to_string(),
                fmt_rate(file.aggregate_rate),
                file.ops.to_string(),
            ]);
            bp_dumps.push(bp.dumps);
            sst_dumps.push(sst.dumps);
            sst_disc.push(sst.discarded);
            bp_fracs = (bp.raw_io_fraction, bp.plugin_fraction);
            sst_fracs = (sst.raw_io_fraction, sst.plugin_fraction);
        }
        let span = |v: &[u64]| {
            let lo = v.iter().min().unwrap();
            let hi = v.iter().max().unwrap();
            if lo == hi {
                lo.to_string()
            } else {
                format!("{lo}-{hi}")
            }
        };
        dumps.row(vec![
            nodes.to_string(),
            span(&bp_dumps),
            span(&sst_dumps),
            span(&sst_disc),
        ]);
        shares.row(vec![
            nodes.to_string(),
            format!("{:.0}%", bp_fracs.0 * 100.0),
            format!("{:.0}%", bp_fracs.1 * 100.0),
            format!("{:.1}%", sst_fracs.0 * 100.0),
            format!("{:.0}%", sst_fracs.1 * 100.0),
        ]);
    }
    print!("{}", fig.render());
    println!();
    print!("{}", dumps.render());
    println!();
    print!("{}", shares.render());
    fig.save_csv("fig6_throughput").ok();
    dumps.save_csv("fig6_dump_counts").ok();
    shares.save_csv("fig6_io_shares").ok();

    // Machine-readable document for the CI perf-regression gate.
    // Computed from the fixed-seed 64-node run (rep 0), so smoke and
    // full sweeps emit identical values; the committed baseline holds
    // conservative bounds (streaming at least matches BP-only) rather
    // than the exact simulated figures.
    let params = Fig6Params { nodes: 64, seed: 1000, ..Default::default() };
    let bp = simulate(Setup::BpOnly, &params);
    let sst = simulate(Setup::SstBp, &params);
    let bp_rate = bp.store_metrics.report(OpKind::Store, 64).aggregate_rate;
    let stream_rate =
        sst.load_metrics.report(OpKind::Load, 64 * 6).aggregate_rate;
    let file_rate =
        sst.file_metrics.report(OpKind::Store, 64).aggregate_rate;
    let mut bj = BenchJson::new("fig6");
    bj.gauge("stream_vs_bp_rate_ratio", stream_rate / bp_rate, true);
    bj.gauge(
        "dump_ratio_sstbp_vs_bp",
        sst.dumps as f64 / bp.dumps.max(1) as f64,
        true,
    );
    bj.info("bp_rate_bytes_s", bp_rate);
    bj.info("stream_rate_bytes_s", stream_rate);
    bj.info("file_rate_bytes_s", file_rate);
    bj.info("sst_discarded", sst.discarded as f64);
    if let Ok(p) = bj.save() {
        println!("\nbench json: {}", p.display());
    }

    println!(
        "\npaper reference @512 nodes: streaming 4.15 TiB/s, SST+BP file \
         2.32 TiB/s, BP-only 1.86 TiB/s; streaming exceeds the 2.5 TiB/s \
         PFS."
    );
}
