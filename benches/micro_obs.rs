//! Overhead gate for the observability layer: with tracing *disabled*
//! (the production default), a hot loop annotated with spans and
//! counters must cost within 3% of the identical loop without them.
//!
//! The workload per iteration is a 4 KiB copy + checksum — sized like a
//! small wire frame, large enough that the disabled-span constant cost
//! (one atomic load + two counter adds) sits far below the gate, small
//! enough that a regression to "always allocate the span record" would
//! blow straight through it. Rounds of the two variants interleave so
//! clock-frequency drift hits both equally, and each variant scores its
//! *minimum* round (noise only ever adds time).

use std::hint::black_box;
use std::time::Instant;

use openpmd_stream::bench::{smoke_mode, BenchJson};
use openpmd_stream::obs::metrics::counter;
use openpmd_stream::obs::trace;
use openpmd_stream::util::cli::Args;

const BUF: usize = 4096;

/// The common payload: copy the frame and fold a checksum over it.
fn workload(src: &[u8], dst: &mut [u8]) -> u64 {
    dst.copy_from_slice(src);
    let mut sum = 0u64;
    for chunk in dst.chunks_exact(8) {
        sum = sum
            .wrapping_mul(0x100_0000_01b3)
            .wrapping_add(u64::from_le_bytes(chunk.try_into().unwrap()));
    }
    sum
}

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "MICRO_OBS_SMOKE");
    let (rounds, iters) = if smoke { (5, 20_000u64) } else { (9, 200_000u64) };

    assert!(
        !trace::enabled(),
        "micro_obs measures the *disabled* path; tracing must be off"
    );

    let src = vec![0xa5u8; BUF];
    let mut dst = vec![0u8; BUF];
    // Interned once, like every production hot path does.
    let ops = counter("obs.bench_ops");
    let bytes = counter("obs.bench_bytes");

    let mut base_min = f64::INFINITY;
    let mut inst_min = f64::INFINITY;
    for _ in 0..rounds {
        // Baseline round: workload only.
        let t = Instant::now();
        for _ in 0..iters {
            black_box(workload(black_box(&src), &mut dst));
        }
        base_min = base_min.min(t.elapsed().as_secs_f64());

        // Instrumented round: same workload under a (disabled) span,
        // with the same counter traffic the wire layer generates.
        let t = Instant::now();
        for _ in 0..iters {
            let mut sp = trace::span("obs.bench_op").with("buf", BUF);
            let sum = black_box(workload(black_box(&src), &mut dst));
            ops.inc();
            bytes.add(BUF as u64);
            sp.set("sum", sum & 0xff);
        }
        inst_min = inst_min.min(t.elapsed().as_secs_f64());
    }

    let base_ns = base_min * 1e9 / iters as f64;
    let inst_ns = inst_min * 1e9 / iters as f64;
    let ratio = inst_ns / base_ns;
    println!(
        "micro_obs: baseline {base_ns:.1} ns/op, instrumented \
         {inst_ns:.1} ns/op, ratio {ratio:.4} ({rounds} rounds x \
         {iters} iters, min-of-rounds)"
    );

    let mut bj = BenchJson::new("obs");
    bj.gauge("overhead_ratio", ratio, false);
    bj.info("baseline_ns_per_op", base_ns);
    bj.info("instrumented_ns_per_op", inst_ns);
    if let Ok(p) = bj.save() {
        println!("bench json: {}", p.display());
    }

    assert!(
        ratio <= 1.03,
        "disabled-tracing overhead {:.2}% exceeds the 3% gate \
         (baseline {base_ns:.1} ns/op, instrumented {inst_ns:.1} ns/op)",
        (ratio - 1.0) * 100.0
    );
    println!("micro_obs: disabled-tracing overhead gate (<=3%) passed");
}
