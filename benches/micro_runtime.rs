//! M3 (ours): measured L1/L2 hot-path cost through PJRT — per-batch
//! latency and throughput of the three artifacts, plus the rust-fallback
//! comparison (how much the XLA-compiled kernels buy on CPU).
//!
//! Skips gracefully when artifacts are absent (`make artifacts`).

use std::time::Duration;

use openpmd_stream::analysis::saxs::{SaxsAnalyzer, BATCH_ATOMS, N_Q};
use openpmd_stream::bench::{bench_loop, smoke_mode, Table};
use openpmd_stream::runtime::Runtime;
use openpmd_stream::util::cli::Args;
use openpmd_stream::util::rng::Rng;

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "MICRO_RUNTIME_SMOKE");
    let (warmup, iters) = if smoke { (1, 3) } else { (3, 10) };
    let budget = if smoke {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(1)
    };

    // Steady-state allocation audit (OpsReport.allocations): drive a
    // small BP write/read cycle and assert the data path's per-step
    // fresh-allocation count (a) stops changing after the first step
    // and (b) is independent of how many chunks a step carries — the
    // buffer pool's O(1) contract. A growing per-step count means a
    // buffer that should recycle regressed into a fresh allocation; a
    // chunk-count-dependent one means per-chunk scratch stopped going
    // through the pool. Runs before the PJRT gate so it holds even
    // where artifacts are absent.
    {
        use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
        use openpmd_stream::adios::engine::{cast, Engine, StepStatus,
                                            VarDecl};
        use openpmd_stream::openpmd::chunk::Chunk;
        use openpmd_stream::openpmd::types::Datatype;
        use openpmd_stream::util::pool;

        /// Write a `chunks`-chunk-per-step BP file, sweep it with
        /// end-of-step payload reclaim (the pipe's discipline), assert
        /// the per-step allocation deltas go steady after warmup, and
        /// return the steady value.
        fn steady_allocs(dir: &std::path::Path, chunks: u64) -> u64 {
            let extent = 1024u64;
            let steps = 6u64;
            let per = extent / chunks;
            let path = dir.join(format!("micro-alloc-{chunks}-{}.bp",
                                        std::process::id()));
            {
                let mut w =
                    BpWriter::create(&path, WriterCtx::default())
                        .unwrap();
                let var =
                    VarDecl::new("/data/x", Datatype::F32, vec![extent]);
                for _ in 0..steps {
                    assert_eq!(w.begin_step().unwrap(), StepStatus::Ok);
                    for c in 0..chunks {
                        let off = c * per;
                        let xs: Vec<f32> =
                            (0..per).map(|i| (off + i) as f32).collect();
                        w.put(&var, Chunk::new(vec![off], vec![per]),
                              cast::f32_to_bytes(&xs))
                            .unwrap();
                    }
                    w.end_step().unwrap();
                }
                w.close().unwrap();
            }
            let mut r = BpReader::open(&path).unwrap();
            let mut per_step = Vec::new();
            let mut last = 0u64;
            while r.begin_step().unwrap() == StepStatus::Ok {
                let data = r
                    .get("/data/x", Chunk::new(vec![0], vec![extent]))
                    .unwrap();
                pool::reclaim_bytes(data);
                r.end_step().unwrap();
                let now = r.ops_report().allocations;
                per_step.push(now - last);
                last = now;
            }
            assert_eq!(per_step.len() as u64, steps);
            let tail = &per_step[1..];
            assert!(
                tail.iter().all(|&d| d == tail[0]),
                "per-step data-path allocations must be steady in \
                 steady state (chunks={chunks}), got {per_step:?}"
            );
            std::fs::remove_file(&path).ok();
            tail[0]
        }

        let dir = std::env::temp_dir().join("openpmd-stream-bench");
        std::fs::create_dir_all(&dir).unwrap();
        let single = steady_allocs(&dir, 1);
        let multi = steady_allocs(&dir, 4);
        if pool::pooling_enabled() {
            assert_eq!(
                single, multi,
                "steady-state allocations/step must be independent of \
                 chunk count: 1 chunk -> {single}, 4 chunks -> {multi}"
            );
            println!(
                "allocation audit: {single} allocation(s)/step, steady \
                 and chunk-count independent"
            );
        } else {
            println!(
                "allocation audit: steady at {single} (1 chunk) / \
                 {multi} (4 chunks) per step; pool disabled, \
                 chunk-independence not asserted"
            );
        }
    }

    let rt = match Runtime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("micro_runtime: skipped ({e:#})");
            return;
        }
    };
    let mut rng = Rng::new(1);
    let mut t = Table::new(
        "M3: PJRT artifact hot-path cost (per batch)",
        &["artifact", "batch", "time/iter", "throughput"],
    );

    // --- saxs: 4096 atoms x 512 q-vectors ------------------------------
    {
        let exec = rt.get("saxs").unwrap();
        let pos: Vec<f32> =
            (0..BATCH_ATOMS * 3).map(|_| rng.f32() * 64.0).collect();
        let w: Vec<f32> = (0..BATCH_ATOMS).map(|_| rng.f32()).collect();
        let q_t = SaxsAnalyzer::polar_q_grid(2.0, N_Q);
        let r = bench_loop("saxs", warmup, iters, budget, || {
            std::hint::black_box(
                exec.run_f32(&[&pos, &w, &q_t]).unwrap());
        });
        // Kinematic sum: ~2*N*Q (phase) + 2*2*N*Q (trig-ish) + 4*N*Q
        let flops = 10.0 * BATCH_ATOMS as f64 * N_Q as f64;
        t.row(vec![
            "saxs (PJRT)".into(),
            format!("{BATCH_ATOMS} atoms x {N_Q} q"),
            openpmd_stream::util::fmt_duration(r.per_iter()),
            format!("{:.2} GFLOP/s-equiv", flops / r.mean / 1e9),
        ]);
        // Fallback comparison at the same batch.
        let mut a = SaxsAnalyzer::new(2.0, None).unwrap();
        let r2 = bench_loop("saxs-fallback", 1, 3,
                            Duration::from_millis(300), || {
            a.consume(&pos, &w).unwrap();
        });
        t.row(vec![
            "saxs (rust fallback)".into(),
            format!("{BATCH_ATOMS} atoms x {N_Q} q"),
            openpmd_stream::util::fmt_duration(r2.per_iter()),
            format!("{:.1}x vs PJRT", r2.mean / r.mean),
        ]);
    }

    // --- pic_step: 16384 particles -------------------------------------
    {
        let exec = rt.get("pic_step").unwrap();
        let n = exec.meta.inputs[0][0] as usize;
        let g = exec.meta.inputs[2][0] as usize;
        let pos: Vec<f32> =
            (0..n * 3).map(|_| rng.f32() * 64.0).collect();
        let mom: Vec<f32> =
            (0..n * 3).map(|_| rng.f32() - 0.5).collect();
        let fields = vec![0.01f32; g * g * 3];
        let r = bench_loop("pic_step", warmup, iters, budget, || {
            std::hint::black_box(
                exec.run_f32(&[&pos, &mom, &fields, &fields]).unwrap());
        });
        t.row(vec![
            "pic_step (PJRT)".into(),
            format!("{n} particles"),
            openpmd_stream::util::fmt_duration(r.per_iter()),
            format!("{:.1} Mparticles/s", n as f64 / r.mean / 1e6),
        ]);
    }

    // --- binning: 16384 samples ----------------------------------------
    {
        let exec = rt.get("binning").unwrap();
        let n = exec.meta.inputs[0][0] as usize;
        let mom: Vec<f32> =
            (0..n * 3).map(|_| rng.f32() - 0.5).collect();
        let w = vec![1.0f32; n];
        let r = bench_loop("binning", warmup, iters, budget, || {
            std::hint::black_box(exec.run_f32(&[&mom, &w]).unwrap());
        });
        t.row(vec![
            "binning (PJRT)".into(),
            format!("{n} samples"),
            openpmd_stream::util::fmt_duration(r.per_iter()),
            format!("{:.1} Msamples/s", n as f64 / r.mean / 1e6),
        ]);
    }

    print!("{}", t.render());
    t.save_csv("micro_runtime").ok();
    println!(
        "\nNote: interpret-mode Pallas on CPU-PJRT measures the *path*, \
         not TPU speed; DESIGN.md SS Perf holds the VMEM/MXU projection \
         for real hardware."
    );
}
