//! M3 (ours): measured L1/L2 hot-path cost through PJRT — per-batch
//! latency and throughput of the three artifacts, plus the rust-fallback
//! comparison (how much the XLA-compiled kernels buy on CPU).
//!
//! Skips gracefully when artifacts are absent (`make artifacts`).

use std::time::Duration;

use openpmd_stream::analysis::saxs::{SaxsAnalyzer, BATCH_ATOMS, N_Q};
use openpmd_stream::bench::{bench_loop, smoke_mode, Table};
use openpmd_stream::runtime::Runtime;
use openpmd_stream::util::cli::Args;
use openpmd_stream::util::rng::Rng;

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "MICRO_RUNTIME_SMOKE");
    let (warmup, iters) = if smoke { (1, 3) } else { (3, 10) };
    let budget = if smoke {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(1)
    };

    // Steady-state allocation audit (OpsReport.allocations): drive a
    // small BP write/read cycle and assert the data path's per-step
    // allocation count stops changing after the first step — a growing
    // per-step count would mean a buffer that should be reused (or a
    // passthrough that should be zero-copy) regressed into a fresh
    // allocation. Runs before the PJRT gate so it holds even where
    // artifacts are absent.
    {
        use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
        use openpmd_stream::adios::engine::{cast, Engine, StepStatus,
                                            VarDecl};
        use openpmd_stream::openpmd::chunk::Chunk;
        use openpmd_stream::openpmd::types::Datatype;

        let dir = std::env::temp_dir().join("openpmd-stream-bench");
        std::fs::create_dir_all(&dir).unwrap();
        let path =
            dir.join(format!("micro-alloc-{}.bp", std::process::id()));
        let steps = 6u64;
        {
            let mut w =
                BpWriter::create(&path, WriterCtx::default()).unwrap();
            let var = VarDecl::new("/data/x", Datatype::F32, vec![1024]);
            let xs: Vec<f32> = (0..1024).map(|i| i as f32).collect();
            for _ in 0..steps {
                assert_eq!(w.begin_step().unwrap(), StepStatus::Ok);
                w.put(&var, Chunk::new(vec![0], vec![1024]),
                      cast::f32_to_bytes(&xs))
                    .unwrap();
                w.end_step().unwrap();
            }
            w.close().unwrap();
        }
        let mut r = BpReader::open(&path).unwrap();
        let mut per_step = Vec::new();
        let mut last = 0u64;
        while r.begin_step().unwrap() == StepStatus::Ok {
            let _ = r.get("/data/x", Chunk::new(vec![0], vec![1024]))
                .unwrap();
            r.end_step().unwrap();
            let now = r.ops_report().allocations;
            per_step.push(now - last);
            last = now;
        }
        assert_eq!(per_step.len() as u64, steps);
        let tail = &per_step[1..];
        assert!(
            tail.iter().all(|&d| d == tail[0]),
            "per-step data-path allocations must be steady in steady \
             state, got {per_step:?}"
        );
        println!(
            "allocation audit: {} allocation(s)/step, steady across \
             {steps} steps",
            tail[0]
        );
        std::fs::remove_file(&path).ok();
    }

    let rt = match Runtime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("micro_runtime: skipped ({e:#})");
            return;
        }
    };
    let mut rng = Rng::new(1);
    let mut t = Table::new(
        "M3: PJRT artifact hot-path cost (per batch)",
        &["artifact", "batch", "time/iter", "throughput"],
    );

    // --- saxs: 4096 atoms x 512 q-vectors ------------------------------
    {
        let exec = rt.get("saxs").unwrap();
        let pos: Vec<f32> =
            (0..BATCH_ATOMS * 3).map(|_| rng.f32() * 64.0).collect();
        let w: Vec<f32> = (0..BATCH_ATOMS).map(|_| rng.f32()).collect();
        let q_t = SaxsAnalyzer::polar_q_grid(2.0, N_Q);
        let r = bench_loop("saxs", warmup, iters, budget, || {
            std::hint::black_box(
                exec.run_f32(&[&pos, &w, &q_t]).unwrap());
        });
        // Kinematic sum: ~2*N*Q (phase) + 2*2*N*Q (trig-ish) + 4*N*Q
        let flops = 10.0 * BATCH_ATOMS as f64 * N_Q as f64;
        t.row(vec![
            "saxs (PJRT)".into(),
            format!("{BATCH_ATOMS} atoms x {N_Q} q"),
            openpmd_stream::util::fmt_duration(r.per_iter()),
            format!("{:.2} GFLOP/s-equiv", flops / r.mean / 1e9),
        ]);
        // Fallback comparison at the same batch.
        let mut a = SaxsAnalyzer::new(2.0, None).unwrap();
        let r2 = bench_loop("saxs-fallback", 1, 3,
                            Duration::from_millis(300), || {
            a.consume(&pos, &w).unwrap();
        });
        t.row(vec![
            "saxs (rust fallback)".into(),
            format!("{BATCH_ATOMS} atoms x {N_Q} q"),
            openpmd_stream::util::fmt_duration(r2.per_iter()),
            format!("{:.1}x vs PJRT", r2.mean / r.mean),
        ]);
    }

    // --- pic_step: 16384 particles -------------------------------------
    {
        let exec = rt.get("pic_step").unwrap();
        let n = exec.meta.inputs[0][0] as usize;
        let g = exec.meta.inputs[2][0] as usize;
        let pos: Vec<f32> =
            (0..n * 3).map(|_| rng.f32() * 64.0).collect();
        let mom: Vec<f32> =
            (0..n * 3).map(|_| rng.f32() - 0.5).collect();
        let fields = vec![0.01f32; g * g * 3];
        let r = bench_loop("pic_step", warmup, iters, budget, || {
            std::hint::black_box(
                exec.run_f32(&[&pos, &mom, &fields, &fields]).unwrap());
        });
        t.row(vec![
            "pic_step (PJRT)".into(),
            format!("{n} particles"),
            openpmd_stream::util::fmt_duration(r.per_iter()),
            format!("{:.1} Mparticles/s", n as f64 / r.mean / 1e6),
        ]);
    }

    // --- binning: 16384 samples ----------------------------------------
    {
        let exec = rt.get("binning").unwrap();
        let n = exec.meta.inputs[0][0] as usize;
        let mom: Vec<f32> =
            (0..n * 3).map(|_| rng.f32() - 0.5).collect();
        let w = vec![1.0f32; n];
        let r = bench_loop("binning", warmup, iters, budget, || {
            std::hint::black_box(exec.run_f32(&[&mom, &w]).unwrap());
        });
        t.row(vec![
            "binning (PJRT)".into(),
            format!("{n} samples"),
            openpmd_stream::util::fmt_duration(r.per_iter()),
            format!("{:.1} Msamples/s", n as f64 / r.mean / 1e6),
        ]);
    }

    print!("{}", t.render());
    t.save_csv("micro_runtime").ok();
    println!(
        "\nNote: interpret-mode Pallas on CPU-PJRT measures the *path*, \
         not TPU speed; DESIGN.md SS Perf holds the VMEM/MXU projection \
         for real hardware."
    );
}
