//! **fig_serve**: writer-side cost vs subscriber fan-out N through
//! the serve daemon — the encode-once / serve-N-times claim, measured.
//!
//! Each cell pumps the same chunked BP fixture through a
//! [`ServeDaemon`] (inproc, `Block` lag policy, a `shuffle|rle`
//! operator chain so staging does real codec work) while N pipe
//! subscribers drain the served stream into counting sinks. The sweep
//! reports upstream ingress, staged encode counts, total egress and
//! the pump wall time per N.
//!
//! Acceptance bar (asserted): ingress bytes and staged operator
//! encodes are IDENTICAL across every N — the daemon encodes each
//! step exactly once no matter how wide the fan-out — and total
//! egress is exactly N-fold (every subscriber receives every staged
//! frame, as `Arc` clones of one buffer).
//!
//! Emits `bench-results/BENCH_serve.json` (shared [`BenchJson`]
//! format): the flatness ratios are gated by the CI `bench-compare`
//! step; absolute throughput is recorded ungated. `--smoke` (or
//! `FIGS_SMOKE=1`) shrinks sizes and the sweep.

use std::time::{Duration, Instant};

use openpmd_stream::adios::engine::Engine;
use openpmd_stream::adios::ops::OpChain;
use openpmd_stream::adios::spec::{ReaderSlot, SourceSpec};
use openpmd_stream::bench::{smoke_mode, BenchJson, Table};
use openpmd_stream::pipeline::pipe::{run_pipe, PipeOptions};
use openpmd_stream::pipeline::serve::{
    LagPolicy, ServeDaemon, ServeOptions, ServeReport,
};
use openpmd_stream::testing::engines::CountingSink;
use openpmd_stream::testing::fixtures;
use openpmd_stream::util::bytes::{fmt_bytes, fmt_rate};
use openpmd_stream::util::cli::Args;

/// Run one fan-out cell: fixture -> daemon -> `subs` pipe
/// subscribers. Returns the daemon's report plus the pump wall time.
fn serve_cell(
    case: &str,
    subs: usize,
    steps: u64,
    extent: u64,
) -> (ServeReport, f64) {
    let src = std::env::temp_dir().join(format!(
        "opmd-figserve-{case}-{}.bp",
        std::process::id()
    ));
    fixtures::write_chunked_bp(&src, steps, extent, 4);
    let mut upstream = SourceSpec::parse(src.to_str().unwrap())
        .expect("source spec")
        .open(ReaderSlot::solo())
        .expect("open upstream");
    let mut daemon = ServeDaemon::start(ServeOptions {
        listen: format!("fig-serve-{case}-{}", std::process::id()),
        transport: "inproc".into(),
        cache_steps: 8,
        lag: LagPolicy::Block,
        operators: Some(OpChain::parse("shuffle|rle").unwrap()),
        ..Default::default()
    })
    .expect("start daemon");
    let addr = daemon.address();

    let mut drains = Vec::with_capacity(subs);
    for _ in 0..subs {
        let spec = format!("serve+{addr}");
        drains.push(std::thread::spawn(move || {
            let mut reader = SourceSpec::parse(&spec)
                .expect("subscriber spec")
                .open(ReaderSlot::solo())
                .expect("open subscriber");
            let mut sink = CountingSink::new();
            let mut popts = PipeOptions::solo();
            popts.idle_timeout = Duration::from_secs(30);
            run_pipe(reader.as_mut(), &mut sink, popts)
                .expect("subscriber pipe");
        }));
    }
    // Every subscriber registers before the pump starts, so all cells
    // announce all steps to all subscribers (Block never sheds) and
    // the egress comparison below is exact, not statistical.
    let deadline = Instant::now() + Duration::from_secs(30);
    while daemon.subscribers() < subs {
        assert!(
            Instant::now() < deadline,
            "{case}: only {}/{subs} subscribers registered",
            daemon.subscribers()
        );
        std::thread::sleep(Duration::from_millis(2));
    }

    let wall = Instant::now();
    let report = daemon.pump(upstream.as_mut()).expect("pump");
    let wall = wall.elapsed().as_secs_f64().max(1e-9);
    upstream.close().expect("close upstream");
    for d in drains {
        d.join().expect("subscriber thread");
    }
    std::fs::remove_file(&src).ok();
    (report, wall)
}

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "FIGS_SMOKE");
    let steps: u64 = if smoke { 4 } else { 8 };
    let extent: u64 = if smoke { 1 << 12 } else { 1 << 16 };
    let sweep: &[usize] = if smoke { &[1, 2, 8] } else { &[1, 4, 16, 64] };

    let mut t = Table::new(
        "fig_serve: BP fixture -> serve daemon -> N subscribers \
         (shuffle|rle staging, inproc, Block lag policy)",
        &["N", "steps", "ingress", "encodes", "egress",
          "egress/ingress", "pump wall", "egress rate"],
    );
    let mut json = BenchJson::new("serve");

    // (ingress bytes, staged encodes, egress bytes) at N = 1: the
    // flatness reference every wider cell is compared against.
    let mut n1: Option<(u64, u64, u64)> = None;
    let mut nmax_cell = (0u64, 0u64, 0u64, 1usize, 1e-9f64);
    for &subs in sweep {
        let (report, wall) =
            serve_cell(&format!("n{subs}"), subs, steps, extent);
        assert_eq!(report.steps_in, steps, "N={subs}: daemon lost steps");
        assert_eq!(
            report.subscribers.len(),
            subs,
            "N={subs}: subscriber accounting is off"
        );
        for s in &report.subscribers {
            assert_eq!(
                s.announced_steps, steps,
                "N={subs}: rank {} missed announces", s.rank
            );
            assert_eq!(
                s.dropped_steps, 0,
                "N={subs}: rank {} lost steps under Block", s.rank
            );
        }
        let encodes = report.ops.chunks_encoded;
        match n1 {
            None => n1 = Some((report.bytes_in, encodes,
                               report.egress_bytes)),
            Some((b1, e1, g1)) => {
                // ACCEPTANCE: writer-side cost is flat in N —
                // identical upstream reads, identical staging encodes;
                // only egress scales, and exactly N-fold.
                assert_eq!(
                    report.bytes_in, b1,
                    "N={subs}: ingress bytes grew with fan-out"
                );
                assert_eq!(
                    encodes, e1,
                    "N={subs}: staging re-encoded for extra subscribers"
                );
                assert_eq!(
                    report.egress_bytes,
                    subs as u64 * g1,
                    "N={subs}: egress is not exactly N-fold"
                );
            }
        }
        nmax_cell = (report.bytes_in, encodes, report.egress_bytes,
                     subs, wall);
        t.row(vec![
            subs.to_string(),
            report.steps_in.to_string(),
            fmt_bytes(report.bytes_in),
            encodes.to_string(),
            fmt_bytes(report.egress_bytes),
            format!(
                "{:.2}x",
                report.egress_bytes as f64
                    / report.bytes_in.max(1) as f64
            ),
            format!("{wall:.3}s"),
            fmt_rate(report.egress_bytes as f64 / wall),
        ]);
        if subs == 1 {
            json.info("n1_pump_bytes_per_s",
                      report.bytes_in as f64 / wall);
        }
    }

    print!("{}", t.render());
    t.save_csv("fig_serve").ok();

    let (b1, e1, g1) = n1.expect("sweep ran at least one cell");
    let (bn, en, gn, nmax, wall_n) = nmax_cell;
    json.gauge(
        "ingress_bytes_ratio_nmax_over_n1",
        bn as f64 / b1.max(1) as f64,
        false,
    );
    json.gauge(
        "staging_encodes_ratio_nmax_over_n1",
        en as f64 / e1.max(1) as f64,
        false,
    );
    json.gauge(
        "egress_per_sub_ratio_nmax_over_n1",
        (gn as f64 / nmax as f64) / g1.max(1) as f64,
        false,
    );
    json.info("nmax_egress_bytes_per_s", gn as f64 / wall_n);
    match json.save() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => println!("\nBENCH_serve.json not written: {e}"),
    }
    println!(
        "acceptance: ingress {} and {} staged encodes identical across \
         N in {sweep:?}; egress exactly N-fold — OK",
        fmt_bytes(b1),
        e1
    );
}
