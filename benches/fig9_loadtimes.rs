//! Regenerates **Fig. 9**: perceived data-loading times of the two
//! best strategies — (1) by hostname and (3) hyperslabs — as boxplots,
//! plus the binpacking worst-case scan the paper describes (the single
//! exchange where Next-Fit sent ~2x the ideal volume to one reader).

use openpmd_stream::bench::fig8::{simulate, Fig8Params};
use openpmd_stream::bench::{smoke_mode, BenchJson, Table};
use openpmd_stream::pipeline::metrics::OpKind;
use openpmd_stream::util::cli::Args;
use openpmd_stream::util::stats::boxplot;

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "FIG9_SMOKE");
    let nodes_sweep: &[usize] =
        if smoke { &[64] } else { &[64, 128, 256, 512] };
    let reps = if smoke { 1 } else { 3 };
    let scan_seeds = if smoke { 4 } else { 24 };
    let mut t = Table::new(
        "Fig 9: perceived data loading times [s], strategies (1) and (3), \
         RDMA (3 reps pooled)",
        &["nodes", "strategy", "n", "w-", "q1", "median", "q3", "w+",
          "max", "outliers"],
    );
    for &nodes in nodes_sweep {
        for (name, label) in [("hostname", "(1) by hostname"),
                              ("hyperslabs", "(3) hyperslabs")] {
            let mut times = Vec::new();
            for rep in 0..reps {
                let run = simulate(&Fig8Params {
                    nodes,
                    strategy: name.into(),
                    steps: 4,
                    seed: 4000 + rep,
                    ..Default::default()
                });
                times.extend(run.load_metrics.durations(OpKind::Load));
            }
            let b = boxplot(&times);
            t.row(vec![
                nodes.to_string(),
                label.into(),
                b.n.to_string(),
                format!("{:.2}", b.lower_whisker),
                format!("{:.2}", b.q1),
                format!("{:.2}", b.median),
                format!("{:.2}", b.q3),
                format!("{:.2}", b.upper_whisker),
                format!("{:.2}", b.max),
                b.outliers.len().to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    t.save_csv("fig9_loadtimes").ok();

    // Machine-readable document for the CI perf-regression gate: the
    // fixed-seed 64-node rep-0 medians for both strategies, identical
    // in smoke and full sweeps. The committed baseline is a
    // conservative ceiling (paper: medians ~0.9 s), so the gate only
    // trips on a blow-up, not on simulator tuning.
    let mut bj = BenchJson::new("fig9");
    for (name, key) in [("hostname", "hostname_median_load_s"),
                        ("hyperslabs", "hyperslabs_median_load_s")] {
        let run = simulate(&Fig8Params {
            nodes: 64,
            strategy: name.into(),
            steps: 4,
            seed: 4000,
            ..Default::default()
        });
        let b = boxplot(&run.load_metrics.durations(OpKind::Load));
        bj.gauge(key, b.median, false);
        bj.info(&format!("{name}_q3_load_s"), b.q3);
    }
    if let Ok(p) = bj.save() {
        println!("\nbench json: {}", p.display());
    }

    // The binpacking worst case: scan seeds until a reader receives
    // ~double the ideal amount in some exchange (paper: observed once at
    // 512 nodes, skewing that scatter plot from ~5 to ~10 minutes).
    println!("\nbinpacking worst-case scan (Next-Fit 2x bound):");
    let mut found = 0;
    for seed in 0..scan_seeds as u64 {
        let run = simulate(&Fig8Params {
            nodes: 64,
            strategy: "binpacking".into(),
            steps: 4,
            seed: 5000 + seed,
            ..Default::default()
        });
        found += run.worst_case_events;
    }
    println!(
        "  {found} reader-exchanges received >=1.9x the ideal volume \
         across {scan_seeds} seeds x 4 exchanges — the worst-case \
         behavior \"does in practice occur\" (SS 4.3), while staying \
         rare."
    );
    println!(
        "\npaper reference: medians ~0.9 s for both strategies at every \
         scale; hostname-strategy outliers at 512 nodes all stem from one \
         exchange with a doubled reader."
    );
}
