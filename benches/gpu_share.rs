//! Regenerates the §4.3 **GPU-share experiment** (D3 in DESIGN.md):
//! shifting GPUs between PIConGPU and GAPD changes the achievable
//! scatter-plot frequency "only by changing the job script".
//!
//! Model (weak scaling per GPU, calibrated to the paper's two quoted
//! points): the producer's particle count scales with its GPU count, so
//! its per-step wall time is constant; GAPD's work scales with the data
//! volume over its GPU count: T_gapd = K * (writer_gpus/3) / (reader_gpus/3)
//! with K = 315 s at the 3+3 split. The analysis-paced output period is
//! the smallest multiple of 100 simulation steps covering T_gapd.

use openpmd_stream::bench::{smoke_mode, Table};
use openpmd_stream::cluster::network::workload;
use openpmd_stream::util::cli::Args;

fn scatter_period(writer_gpus: usize, reader_gpus: usize) -> (f64, u64) {
    let t_gapd = workload::GAPD_COMPUTE_3GPU * (writer_gpus as f64 / 3.0)
        / (reader_gpus as f64 / 3.0);
    let steps = t_gapd / workload::SIM_SECONDS_PER_STEP;
    // Output pacing: next multiple of 100 steps that covers T_gapd.
    let period = (steps / 100.0).ceil() as u64 * 100;
    (t_gapd, period.max(100))
}

fn main() {
    // Closed-form model, already instant: --smoke is accepted for
    // harness uniformity but changes nothing.
    let args = Args::from_env(false).unwrap_or_default();
    let _ = smoke_mode(&args, "GPU_SHARE_SMOKE");
    let mut t = Table::new(
        "SS 4.3: GPU-share shift on a 6-GPU node (PIConGPU + GAPD)",
        &["PIConGPU GPUs", "GAPD GPUs", "GAPD time/plot [s]",
          "scatter plot every N steps", "plots per hour"],
    );
    for writer_gpus in 1..=5usize {
        let reader_gpus = 6 - writer_gpus;
        let (t_gapd, period) = scatter_period(writer_gpus, reader_gpus);
        let plots_per_hour =
            3600.0 / (period as f64 * workload::SIM_SECONDS_PER_STEP);
        t.row(vec![
            writer_gpus.to_string(),
            reader_gpus.to_string(),
            format!("{t_gapd:.0}"),
            period.to_string(),
            format!("{plots_per_hour:.1}"),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("gpu_share").ok();

    // The paper's two quoted operating points must fall out exactly.
    let (t33, p33) = scatter_period(3, 3);
    let (t15, p15) = scatter_period(1, 5);
    println!("\npaper reference: 3+3 -> ~315 s per plot, every 2000 steps; \
              1+5 -> ~1 min, every 400 steps.");
    println!("ours:            3+3 -> {t33:.0} s, every {p33} steps; \
              1+5 -> {t15:.0} s, every {p15} steps.");
    assert_eq!(p33, 2000);
    assert_eq!(p15, 400);
    assert!((t15 - 63.0).abs() < 1.0);
    println!("match: OK (no application code changed — a scheduling \
              decision, which is the point of loose coupling).");
}
