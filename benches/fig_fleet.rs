//! **fig_fleet**: aggregate fleet throughput vs reader count M and
//! distribution strategy, over a live N=2-writer SST stream with a
//! deliberately skewed chunk table.
//!
//! Each writer rank publishes one 8x-skewed chunk plus three small
//! ones per step (the load-imbalanced-producer shape of §4.3), so a
//! strategy that ignores sizes (RoundRobin) piles both 8x chunks onto
//! one reader while the cost-aware LoadBalanced (LPT over announced
//! staged bytes) gives each its own rank. The sweep reports aggregate
//! forwarded throughput, per-rank byte loads and the max/mean
//! imbalance from the fleet's [`FleetReport`] straggler accounting.
//!
//! Acceptance bar (asserted): at M = 4, LoadBalanced's max-rank bytes
//! <= RoundRobin's on the skewed table, and every cell forwards the
//! complete byte volume (union conservation).
//!
//! Emits `bench-results/BENCH_fleet.json` (shared [`BenchJson`]
//! format): structural metrics (imbalance, LB/RR max-byte ratio) are
//! gated by the CI `bench-compare` step; absolute throughput is
//! recorded ungated. `--smoke` (or `FIGF_SMOKE=1`) shrinks sizes.

use std::sync::Arc;
use std::time::Duration;

use openpmd_stream::adios::engine::Engine;
use openpmd_stream::adios::sst::{SstReader, SstReaderOptions};
use openpmd_stream::bench::{smoke_mode, BenchJson, Table};
use openpmd_stream::distribution::{by_name, Strategy};
use openpmd_stream::pipeline::fleet::{run_fleet, FleetOptions};
use openpmd_stream::pipeline::FleetReport;
use openpmd_stream::openpmd::series::open_shard_family;
use openpmd_stream::pipeline::pipe::{run_pipe, PipeOptions};
use openpmd_stream::testing::engines::CountingSink;
use openpmd_stream::testing::fleet_conformance::{
    cleanup_family, fleet_into_shards, spawn_skewed_sst_writers,
};
use openpmd_stream::util::bytes::{fmt_bytes, fmt_rate};
use openpmd_stream::util::cli::Args;

const WRITERS: usize = 2;
/// Per-writer chunk sizes in units of `k` elements: one 8x straggler
/// chunk plus three small ones.
const SKEW: [u64; 4] = [8, 1, 1, 1];

fn per_writer_elems(k: u64) -> u64 {
    SKEW.iter().sum::<u64>() * k
}

/// Run one (M, strategy) fleet cell over a fresh stream. The writers
/// come from the fleet-conformance harness's shared fixture, so the
/// bench exercises exactly the staging contract the test suite proves.
fn fleet_cell(
    case: &str,
    readers: usize,
    strategy_name: &str,
    steps: u64,
    k: u64,
) -> FleetReport {
    let (addrs, producer_threads) = spawn_skewed_sst_writers(
        case,
        WRITERS,
        steps,
        SKEW.iter().map(|f| f * k).collect(),
        "/data/0/x",
    )
    .expect("spawn skewed writers");
    let mut inputs: Vec<Box<dyn Engine>> = Vec::with_capacity(readers);
    let mut outputs: Vec<Box<dyn Engine>> = Vec::with_capacity(readers);
    for rank in 0..readers {
        inputs.push(Box::new(
            SstReader::open(SstReaderOptions {
                writers: addrs.clone(),
                transport: "inproc".into(),
                rank,
                hostname: "localhost".into(),
                begin_step_timeout: Duration::from_secs(30),
                codecs: None,
            })
            .expect("open fleet reader"),
        ));
        outputs.push(Box::new(CountingSink::new()));
    }
    let strategy: Arc<dyn Strategy> =
        Arc::from(by_name(strategy_name).unwrap());
    let mut opts = FleetOptions::local(readers, strategy).unwrap();
    opts.idle_timeout = Duration::from_secs(30);
    let report = run_fleet(inputs, outputs, opts).expect("fleet run");
    for t in producer_threads {
        t.join().expect("producer thread");
    }
    report
}

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "FIGF_SMOKE");
    let steps: u64 = if smoke { 3 } else { 8 };
    let k: u64 = if smoke { 1 << 10 } else { 1 << 14 };
    let step_bytes = WRITERS as u64 * per_writer_elems(k) * 4;

    let mut t = Table::new(
        "fig_fleet: N=2 skewed SST writers -> M-reader fleet \
         (per-step table: 2 x [8k,k,k,k] chunks)",
        &["M", "strategy", "steps", "aggregate", "max rank",
          "mean rank", "imbalance"],
    );

    let mut json = BenchJson::new("fleet");
    let mut rr_m4_max = 0u64;
    let mut lb_m4_max = u64::MAX;
    for &readers in &[1usize, 2, 4] {
        for strategy in ["roundrobin", "binpacking", "loadbalanced"] {
            let case = format!("m{readers}-{strategy}");
            let report = fleet_cell(&case, readers, strategy, steps, k);
            assert_eq!(report.steps(), steps,
                       "{case}: fleet lost steps");
            assert_eq!(
                report.total_bytes_in(),
                steps * step_bytes,
                "{case}: union does not conserve the stream's bytes"
            );
            if readers == 4 && strategy == "roundrobin" {
                rr_m4_max = report.max_rank_bytes();
                json.gauge("m4_roundrobin_imbalance",
                           report.imbalance(), false);
            }
            if readers == 4 && strategy == "loadbalanced" {
                lb_m4_max = report.max_rank_bytes();
                json.gauge("m4_loadbalanced_imbalance",
                           report.imbalance(), false);
                json.info("m4_loadbalanced_bytes_per_s",
                          report.aggregate_rate());
            }
            if readers == 1 && strategy == "roundrobin" {
                json.info("m1_bytes_per_s", report.aggregate_rate());
            }
            t.row(vec![
                readers.to_string(),
                strategy.into(),
                report.steps().to_string(),
                fmt_rate(report.aggregate_rate()),
                fmt_bytes(report.max_rank_bytes()),
                fmt_bytes(report.mean_rank_bytes() as u64),
                format!("{:.2}x", report.imbalance()),
            ]);
        }
    }
    // Reassembly row: run one fleet into REAL BP shards plus the
    // merged index, reopen the family through the index as ONE
    // multiplexed logical series, and forward it through the serial
    // pipe — the closed produce → fleet → reassemble → consume chain.
    // Recorded ungated (absolute throughput).
    {
        let (index, shards) =
            fleet_into_shards("figf-reasm", "roundrobin", 2, 0)
                .expect("fleet into shards");
        let mut input =
            open_shard_family(&index).expect("open shard family");
        let mut sink = CountingSink::new();
        let mut popts = PipeOptions::solo();
        popts.idle_timeout = Duration::from_secs(30);
        let rep = run_pipe(&mut input, &mut sink, popts)
            .expect("reassembling pipe");
        cleanup_family(&index, &shards);
        assert!(rep.steps > 0, "reassembly forwarded no steps");
        let rate =
            rep.bytes_out as f64 / rep.overlap.wall_seconds.max(1e-9);
        t.row(vec![
            "2".into(),
            "fleet+reassemble".into(),
            rep.steps.to_string(),
            fmt_rate(rate),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
        json.info("m2_reassemble_bytes_per_s", rate);
    }

    print!("{}", t.render());
    t.save_csv("fig_fleet").ok();

    // ACCEPTANCE: the cost-aware strategy must not straggle worse than
    // dealing blind on a skewed table.
    assert!(
        lb_m4_max <= rr_m4_max,
        "ACCEPTANCE: LoadBalanced max-rank bytes {lb_m4_max} > \
         RoundRobin {rr_m4_max} on the skewed table"
    );
    json.gauge(
        "lb_over_rr_max_rank_bytes",
        lb_m4_max as f64 / rr_m4_max.max(1) as f64,
        false,
    );
    match json.save() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => println!("\nBENCH_fleet.json not written: {e}"),
    }
    println!(
        "acceptance: LoadBalanced max-rank bytes {} <= RoundRobin {} \
         at M=4 — OK",
        fmt_bytes(lb_m4_max),
        fmt_bytes(rr_m4_max)
    );
}
