//! M1 (ours): distribution-strategy ablation — algorithm runtime and
//! assignment quality vs chunk count. Measured (not simulated): this is
//! the L3 hot path that runs once per streamed step on the reader side.

use std::time::Duration;

use openpmd_stream::bench::{bench_loop, smoke_mode, Table};
use openpmd_stream::distribution::{by_name, metrics, ChunkTable,
                                   ReaderLayout};
use openpmd_stream::openpmd::chunk::{Chunk, WrittenChunkInfo};
use openpmd_stream::util::cli::Args;
use openpmd_stream::util::rng::Rng;

fn make_table(writers: usize, per_node: usize, jitter: f64,
              seed: u64) -> ChunkTable {
    let mut rng = Rng::new(seed);
    let mut chunks = Vec::new();
    let mut off = 0u64;
    for w in 0..writers {
        let size =
            (1_000_000.0 * (1.0 + jitter * (2.0 * rng.f64() - 1.0))) as u64;
        chunks.push(WrittenChunkInfo::new(
            Chunk::new(vec![off], vec![size]),
            w,
            format!("node{:04}", w / per_node),
        ));
        off += size;
    }
    rng.shuffle(&mut chunks);
    ChunkTable { dataset_extent: vec![off], chunks }
}

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "MICRO_DISTRIBUTION_SMOKE");
    let sweep: &[usize] =
        if smoke { &[48, 384] } else { &[48, 384, 1536, 6144] };
    let strategies = ["roundrobin", "hyperslabs", "binpacking",
                      "loadbalanced", "hostname"];
    let mut t = Table::new(
        "M1: strategy runtime + quality vs scale (3 writers+3 readers/node)",
        &["writers", "strategy", "time/distribute", "balance", "locality",
          "alignment", "max partners"],
    );
    for &writers in sweep {
        let table = make_table(writers, 3, 0.1, 9);
        let readers = ReaderLayout::nodes(writers / 3, 3).unwrap();
        for name in strategies {
            let strategy = by_name(name).unwrap();
            let result = bench_loop(
                name,
                2,
                10,
                Duration::from_millis(200),
                || {
                    std::hint::black_box(
                        strategy.distribute(&table, &readers));
                },
            );
            let a = strategy.distribute(&table, &readers);
            let q = metrics::quality(&table, &readers, &a);
            t.row(vec![
                writers.to_string(),
                name.into(),
                openpmd_stream::util::fmt_duration(result.per_iter()),
                format!("{:.2}", q.balance_factor),
                format!("{:.0}%", q.locality_fraction * 100.0),
                format!("{:.2}", q.alignment),
                q.max_partners.to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    t.save_csv("micro_distribution").ok();
    println!(
        "\nablation takeaway: hostname keeps locality at 100%, \
         binpacking bounds balance by 2.0, loadbalanced (LPT) tracks \
         balance without cutting chunks; all cost O(chunks log chunks) \
         per step, microseconds even at 6k writers — distribution \
         planning is never the streaming bottleneck."
    );
}
