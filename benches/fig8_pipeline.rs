//! Regenerates **Fig. 8**: perceived total throughput of the §4.2
//! PIConGPU→GAPD pipeline for the three distribution strategies of §4.3
//! over both transports:
//!
//!   (1) by hostname (Binpacking within the node),
//!   (2) Binpacking only (topology-blind),
//!   (3) dataset slicing into hyperslabs,
//!
//! each x {RDMA, sockets}, with sockets swept only to 256 nodes (as in
//! the paper). Three repetitions per cell.
//!
//! **Second table — the staged pipe, measured not simulated**: the real
//! `openpmd-pipe` over real BP engines with injected per-stage latency
//! (`testing::engines::InjectedEngine`), serial vs. depth-2 vs. depth-4.
//! The overlapped rows must show wall-clock per step *below* the serial
//! load+store sum — the read-ahead hiding one stage behind the other.
//!
//! `--smoke` (or `FIG8_SMOKE=1`) shrinks both tables to seconds of
//! runtime; CI runs it so a staged-pipe deadlock fails fast instead of
//! hanging the job.

use std::time::Duration;

use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
use openpmd_stream::bench::fig8::{simulate, Fig8Params};
use openpmd_stream::bench::{smoke_mode, BenchJson, Table};
use openpmd_stream::cluster::network::TransportKind;
use openpmd_stream::pipeline::metrics::OpKind;
use openpmd_stream::pipeline::pipe::{run, PipeOptions};
use openpmd_stream::testing::engines::InjectedEngine;
use openpmd_stream::testing::fixtures;
use openpmd_stream::util::bytes::fmt_rate;
use openpmd_stream::util::cli::Args;
use openpmd_stream::util::stats;

fn des_sweep(smoke: bool) {
    let strategies: [(&str, &str); 3] = [
        ("hostname", "(1) by hostname"),
        ("binpacking", "(2) binpacking"),
        ("hyperslabs", "(3) hyperslabs"),
    ];
    let reps: u64 = if smoke { 1 } else { 3 };
    let mut t = Table::new(
        "Fig 8: perceived total throughput, strategies x transports \
         (mean over reps)",
        &["nodes", "transport", "strategy", "throughput", "per-writer"],
    );
    for transport in [TransportKind::Rdma, TransportKind::Tcp] {
        let sweep: &[usize] = if smoke {
            &[16]
        } else {
            match transport {
                TransportKind::Rdma => &[64, 128, 256, 512],
                TransportKind::Tcp => &[64, 128, 256], // paper stops at 256
            }
        };
        for &nodes in sweep {
            for (name, label) in strategies {
                let mut rates = Vec::new();
                let mut per_writer = Vec::new();
                for rep in 0..reps {
                    let run = simulate(&Fig8Params {
                        nodes,
                        transport,
                        strategy: name.into(),
                        steps: if smoke { 2 } else { 4 },
                        seed: 3000 + rep,
                        ..Default::default()
                    });
                    let rep =
                        run.store_metrics.report(OpKind::Store, run.writers);
                    rates.push(rep.aggregate_rate);
                    per_writer.push(rep.mean_instance_rate);
                }
                t.row(vec![
                    nodes.to_string(),
                    transport.label().into(),
                    label.into(),
                    fmt_rate(stats::mean(&rates)),
                    fmt_rate(stats::mean(&per_writer)),
                ]);
            }
        }
    }
    print!("{}", t.render());
    t.save_csv("fig8_pipeline").ok();
    println!(
        "\npaper reference @512 nodes RDMA: (1) 4.93, (2) 1.35, \
         (3) 5.12 TiB/s; @256 sockets: 995 / 15 / 985 GiB/s. Expected \
         shape: (1) ~= (3) >> (2); RDMA >> sockets; sockets+binpacking \
         collapses."
    );
}

/// The real pipe over real BP engines with injected per-stage latency:
/// serial vs. staged at increasing read-ahead depth.
fn staged_pipe_rows(smoke: bool) {
    let steps: u64 = if smoke { 4 } else { 16 };
    let elems: u64 = if smoke { 1 << 10 } else { 1 << 16 };
    let latency = Duration::from_millis(if smoke { 2 } else { 5 });

    let src = std::env::temp_dir()
        .join(format!("fig8-pipe-src-{}.bp", std::process::id()));
    fixtures::write_chunked_bp(&src, steps, elems, 1);

    let mut t = Table::new(
        "Staged pipe (measured): BP->BP identity with injected \
         per-stage latency",
        &["pipe", "wall/step", "load+store/step", "hidden/step",
          "overlap"],
    );
    let mut serial_sum_per_step = 0.0f64;
    let mut best_staged_wall = f64::MAX;
    let mut best_efficiency = 0.0f64;
    for depth in [0usize, 2, 4] {
        let dst = std::env::temp_dir().join(format!(
            "fig8-pipe-dst{depth}-{}.bp",
            std::process::id()
        ));
        let mut input = InjectedEngine::slow(
            BpReader::open(&src).unwrap(), latency, Duration::ZERO);
        let mut output = InjectedEngine::slow(
            BpWriter::create(&dst, WriterCtx::default()).unwrap(),
            Duration::ZERO, latency);
        let mut opts = PipeOptions::solo();
        opts.depth = depth;
        let report = run(&mut input, &mut output, opts).unwrap();
        assert_eq!(report.steps, steps, "pipe lost steps at depth {depth}");
        let o = report.overlap;
        let per = |x: f64| 1e3 * x / steps as f64;
        if depth == 0 {
            serial_sum_per_step = per(o.serial_estimate());
        } else {
            best_staged_wall = best_staged_wall.min(per(o.wall_seconds));
            best_efficiency =
                best_efficiency.max(o.overlap_efficiency());
        }
        t.row(vec![
            if depth == 0 {
                "serial (depth 0)".into()
            } else {
                format!("staged depth {depth}")
            },
            format!("{:.2} ms", per(o.wall_seconds)),
            format!("{:.2} ms", per(o.serial_estimate())),
            format!("{:.2} ms", per(o.hidden_seconds())),
            format!("{:.0}%", 100.0 * o.overlap_efficiency()),
        ]);
        std::fs::remove_file(&dst).ok();
    }
    std::fs::remove_file(&src).ok();
    print!("\n{}", t.render());
    t.save_csv("fig8_pipeline_staged").ok();
    println!(
        "\noverlap check: best staged wall/step {best_staged_wall:.2} ms \
         vs serial load+store {serial_sum_per_step:.2} ms -> {}",
        if best_staged_wall < serial_sum_per_step {
            "OVERLAPPED (store hidden behind load)"
        } else {
            "NO OVERLAP — staged pipe regression?"
        }
    );

    // Machine-readable gate: overlap efficiency and the staged/serial
    // wall ratio are structural (latency is injected, so they hold on
    // any machine); absolute per-step walls ride along ungated.
    let mut json = BenchJson::new("fig8");
    json.gauge("overlap_efficiency_best", best_efficiency, true);
    json.gauge(
        "staged_wall_over_serial_sum",
        if serial_sum_per_step > 0.0 {
            best_staged_wall / serial_sum_per_step
        } else {
            1.0
        },
        false,
    );
    json.info("serial_ms_per_step", serial_sum_per_step);
    json.info("staged_best_ms_per_step", best_staged_wall);
    match json.save() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => println!("BENCH_fig8.json not written: {e}"),
    }
}

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "FIG8_SMOKE");
    des_sweep(smoke);
    staged_pipe_rows(smoke);
}
