//! Regenerates **Fig. 8**: perceived total throughput of the §4.2
//! PIConGPU→GAPD pipeline for the three distribution strategies of §4.3
//! over both transports:
//!
//!   (1) by hostname (Binpacking within the node),
//!   (2) Binpacking only (topology-blind),
//!   (3) dataset slicing into hyperslabs,
//!
//! each x {RDMA, sockets}, with sockets swept only to 256 nodes (as in
//! the paper). Three repetitions per cell.

use openpmd_stream::bench::fig8::{simulate, Fig8Params};
use openpmd_stream::bench::Table;
use openpmd_stream::cluster::network::TransportKind;
use openpmd_stream::pipeline::metrics::OpKind;
use openpmd_stream::util::bytes::fmt_rate;
use openpmd_stream::util::stats;

fn main() {
    let strategies: [(&str, &str); 3] = [
        ("hostname", "(1) by hostname"),
        ("binpacking", "(2) binpacking"),
        ("hyperslabs", "(3) hyperslabs"),
    ];
    let mut t = Table::new(
        "Fig 8: perceived total throughput, strategies x transports \
         (mean over 3 reps)",
        &["nodes", "transport", "strategy", "throughput", "per-writer"],
    );
    for transport in [TransportKind::Rdma, TransportKind::Tcp] {
        let sweep: &[usize] = match transport {
            TransportKind::Rdma => &[64, 128, 256, 512],
            TransportKind::Tcp => &[64, 128, 256], // paper stops at 256
        };
        for &nodes in sweep {
            for (name, label) in strategies {
                let mut rates = Vec::new();
                let mut per_writer = Vec::new();
                for rep in 0..3 {
                    let run = simulate(&Fig8Params {
                        nodes,
                        transport,
                        strategy: name.into(),
                        steps: 4,
                        seed: 3000 + rep,
                        ..Default::default()
                    });
                    let rep =
                        run.store_metrics.report(OpKind::Store, run.writers);
                    rates.push(rep.aggregate_rate);
                    per_writer.push(rep.mean_instance_rate);
                }
                t.row(vec![
                    nodes.to_string(),
                    transport.label().into(),
                    label.into(),
                    fmt_rate(stats::mean(&rates)),
                    fmt_rate(stats::mean(&per_writer)),
                ]);
            }
        }
    }
    print!("{}", t.render());
    t.save_csv("fig8_pipeline").ok();
    println!(
        "\npaper reference @512 nodes RDMA: (1) 4.93, (2) 1.35, \
         (3) 5.12 TiB/s; @256 sockets: 995 / 15 / 985 GiB/s. Expected \
         shape: (1) ~= (3) >> (2); RDMA >> sockets; sockets+binpacking \
         collapses."
    );
}
