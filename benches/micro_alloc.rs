//! Allocation-path micro-bench: A/B of the buffer pool on the BP read
//! hot path (the reassembly-heavy shape the pool exists for).
//!
//! The workload is a chunked BP sweep — every step's whole-variable get
//! takes the multi-record slow path: one zeroed assembly buffer plus
//! one scratch fetch per chunk, with each payload handed back via
//! `pool::reclaim_bytes` at end of step, exactly like the pipe's serial
//! loop. Pooled and pool-bypassed rounds interleave (clock drift hits
//! both equally) and each variant scores its minimum round.
//!
//! Emits `bench-results/BENCH_alloc.json`; the gated `pooled_speedup`
//! metric (bypassed time / pooled time, higher is better) is diffed
//! against `bench/baseline/BENCH_alloc.json` by the bench-compare CI
//! step, which fails the job if pooling regresses to materially slower
//! than plain allocation.

use std::hint::black_box;
use std::path::Path;
use std::time::Instant;

use openpmd_stream::adios::bp::BpReader;
use openpmd_stream::adios::engine::{Engine, StepStatus};
use openpmd_stream::bench::{smoke_mode, BenchJson};
use openpmd_stream::obs::metrics::snapshot_metrics;
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::testing::fixtures;
use openpmd_stream::util::cli::Args;
use openpmd_stream::util::pool;

/// 64 Ki f32 elements = 256 KiB per step, split into 8 chunks of
/// 32 KiB — big enough that allocator traffic is measurable, small
/// enough for a smoke run.
const EXTENT: u64 = 1 << 16;
const CHUNKS: u64 = 8;

/// One full-file sweep: per step, a whole-variable get (multi-chunk
/// reassembly) whose payload is reclaimed at end of step. Returns
/// (seconds, data-path allocations, steps).
fn sweep(path: &Path) -> (f64, u64, u64) {
    let mut r = BpReader::open(path).unwrap();
    let t = Instant::now();
    let mut steps = 0u64;
    while r.begin_step().unwrap() == StepStatus::Ok {
        let data = r
            .get("/data/x", Chunk::whole(vec![EXTENT]))
            .unwrap();
        black_box(&data[..]);
        pool::reclaim_bytes(data);
        r.end_step().unwrap();
        steps += 1;
    }
    let secs = t.elapsed().as_secs_f64();
    let allocs = r.ops_report().allocations;
    r.close().ok();
    (secs, allocs, steps)
}

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "MICRO_ALLOC_SMOKE");
    let (rounds, steps) = if smoke { (3, 8u64) } else { (7, 48u64) };

    let dir = std::env::temp_dir().join("openpmd-stream-bench");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("micro-allocab-{}.bp", std::process::id()));
    fixtures::write_chunked_bp(&path, steps, EXTENT, CHUNKS);

    // Warm the shelves so the pooled variant measures steady state,
    // not first-touch misses.
    pool::set_pooling_enabled(true);
    let _ = sweep(&path);

    let before = snapshot_metrics();
    let mut pooled_min = f64::INFINITY;
    let mut bypass_min = f64::INFINITY;
    let mut pooled_allocs = 0u64;
    let mut bypass_allocs = 0u64;
    for _ in 0..rounds {
        pool::set_pooling_enabled(true);
        let (secs, allocs, n) = sweep(&path);
        assert_eq!(n, steps);
        pooled_min = pooled_min.min(secs);
        pooled_allocs = allocs;

        pool::set_pooling_enabled(false);
        let (secs, allocs, n) = sweep(&path);
        assert_eq!(n, steps);
        bypass_min = bypass_min.min(secs);
        bypass_allocs = allocs;
    }
    pool::set_pooling_enabled(true);
    let delta = snapshot_metrics().delta(&before);

    let pooled_us = pooled_min * 1e6 / steps as f64;
    let bypass_us = bypass_min * 1e6 / steps as f64;
    let speedup = bypass_min / pooled_min;
    let pooled_per_step = pooled_allocs as f64 / steps as f64;
    let bypass_per_step = bypass_allocs as f64 / steps as f64;
    let hits = delta.counter("pool.hits");
    let misses = delta.counter("pool.misses");
    let hit_ratio = if hits + misses > 0 {
        hits as f64 / (hits + misses) as f64
    } else {
        0.0
    };

    println!(
        "micro_alloc: pooled {pooled_us:.1} us/step \
         ({pooled_per_step:.1} alloc/step), bypassed {bypass_us:.1} \
         us/step ({bypass_per_step:.1} alloc/step), speedup \
         {speedup:.3}x ({rounds} rounds x {steps} steps, \
         min-of-rounds; {hits} pool hits / {misses} misses)"
    );

    // A warmed pool must serve the sweep without fresh allocations;
    // the bypassed run allocates per chunk per step. This is the
    // "O(1) steady-state allocations" contract, asserted where the
    // numbers are produced.
    assert_eq!(
        pooled_allocs, 0,
        "warmed pooled sweep still allocated {pooled_allocs} times"
    );
    assert!(
        bypass_per_step >= CHUNKS as f64,
        "bypassed sweep should allocate per chunk per step, got \
         {bypass_per_step:.1}/step"
    );

    let mut bj = BenchJson::new("alloc");
    bj.gauge("pooled_speedup", speedup, true);
    bj.info("pooled_us_per_step", pooled_us);
    bj.info("bypassed_us_per_step", bypass_us);
    bj.info("pooled_allocs_per_step", pooled_per_step);
    bj.info("bypassed_allocs_per_step", bypass_per_step);
    bj.info("pool_hit_ratio", hit_ratio);
    if let Ok(p) = bj.save() {
        println!("bench json: {}", p.display());
    }

    std::fs::remove_file(&path).ok();
}
