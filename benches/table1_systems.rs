//! Regenerates **Table 1**: system performance from OLCF Titan to
//! Frontier, including the storage-requirement column (50 full-GPU-memory
//! dumps) and the §1.1 derived quantities (per-GPU PFS share, growth
//! factors).

use openpmd_stream::bench::{smoke_mode, Table};
use openpmd_stream::cluster::systems::{self, FRONTIER, SUMMIT, TITAN};
use openpmd_stream::util::bytes::{MIB, PIB, TIB};
use openpmd_stream::util::cli::Args;

fn main() {
    // Static table, already instant: --smoke is accepted for harness
    // uniformity but changes nothing.
    let args = Args::from_env(false).unwrap_or_default();
    let _ = smoke_mode(&args, "TABLE1_SMOKE");
    let mut t = Table::new(
        "Table 1: system performance, OLCF Titan -> Frontier",
        &["system", "year", "compute [PFlop/s]", "PFS bw [TiB/s]",
          "capacity [PiB]", "50-dump storage [PiB]",
          "PFS share/GPU [MiB/s]"],
    );
    for s in systems::table1_systems() {
        let (blo, bhi) = s.pfs_bandwidth;
        let (clo, chi) = s.pfs_capacity;
        t.row(vec![
            s.name.into(),
            s.year.to_string(),
            format!("{}", s.compute_pflops),
            if blo == bhi {
                format!("{:.1}", blo / TIB as f64)
            } else {
                format!("{:.0}-{:.0}", blo / TIB as f64, bhi / TIB as f64)
            },
            if clo == chi {
                format!("{:.0}", clo / PIB as f64)
            } else {
                format!("{:.0}-{:.0}", clo / PIB as f64, chi / PIB as f64)
            },
            format!("{:.1}",
                    s.storage_requirement(50) as f64 / PIB as f64),
            format!("{:.0}", s.pfs_share_per_gpu() / MIB as f64),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("table1_systems").ok();

    println!("\nSS 1.1 growth factors (paper: compute ~7.4x / >7.5x, \
              bandwidth 2.5x / 2-4x):");
    println!(
        "  Titan->Summit:    compute {:.1}x, PFS bandwidth {:.1}x",
        SUMMIT.compute_factor_over(&TITAN),
        SUMMIT.bandwidth_factor_over(&TITAN).0
    );
    let (flo, fhi) = FRONTIER.bandwidth_factor_over(&SUMMIT);
    println!(
        "  Summit->Frontier: compute {:.1}x, PFS bandwidth {flo:.0}-{fhi:.0}x",
        FRONTIER.compute_factor_over(&SUMMIT)
    );
    println!("\npaper-vs-ours: storage need Titan 5.3 / Summit 21.1 PiB; \
              per-GPU share Titan 56 / Summit 95 MiB/s.");
}
