//! **fig_compression**: operator throughput vs. compression ratio.
//!
//! The paper's streaming rates are ultimately bound by bytes moved per
//! step; per-variable operators (`adios::ops`) trade CPU for bytes.
//! This bench quantifies the trade on both axes:
//!
//! * **Table 1 (codec micro)** — every chain over one step of the
//!   synthetic producer's openPMD fields (plus `delta` over monotone
//!   u64 index data): compression ratio, encode and decode throughput.
//! * **Table 2 (end-to-end SST-TCP)** — the same producer streaming
//!   over a real TCP socket to an SST reader, identity vs. operated:
//!   wire bytes, wire ratio, and *effective* stream throughput (raw
//!   bytes delivered per wall second). Lossless runs are verified
//!   byte-identical to the identity run.
//!
//! `--smoke` (or `FIGC_SMOKE=1`) shrinks sizes for CI, which runs it so
//! an operator-path regression on the real wire fails fast.
//!
//! Acceptance bar (asserted): `shuffle|rle` on the producer's fields
//! reaches ratio > 1.5x and the end-to-end output stays byte-identical.
//!
//! Emits `bench-results/BENCH_compression.json`: the compression
//! ratios gate the CI `bench-compare` regression step; wall-clock
//! throughput is recorded ungated (shared runners are too noisy to
//! gate on absolutes).

use std::time::{Duration, Instant};

use openpmd_stream::adios::engine::{cast, Engine, StepStatus};
use openpmd_stream::adios::ops::{self, OpChain, OpCtx, OpsReport};
use openpmd_stream::adios::sst::{
    QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions, SstWriter,
    SstWriterOptions,
};
use openpmd_stream::bench::{smoke_mode, BenchJson, Table};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::types::Datatype;
use openpmd_stream::producer::SyntheticProducer;
use openpmd_stream::util::bytes::{fmt_bytes, fmt_rate};
use openpmd_stream::util::cli::Args;

const SEED: u64 = 2024;

fn codec_micro(smoke: bool, json: &mut BenchJson) {
    let particles: usize = if smoke { 1 << 12 } else { 1 << 17 };
    let mut producer =
        SyntheticProducer::new(0, particles, 0, particles as u64, SEED);
    let payloads = producer.component_payloads();

    let mut t = Table::new(
        "fig_compression 1: codec chains over one synthetic producer \
         step (position ramp / momentum noise / constant weighting)",
        &["chain", "ratio", "saved", "encode", "decode"],
    );

    let mut shuffle_rle_ratio = 0.0f64;
    for spec in ["identity", "shuffle", "rle", "shuffle|rle", "zfp:14",
                 "zfp:14|shuffle|rle"] {
        let chain = OpChain::parse(spec).unwrap();
        let mut rep = OpsReport::default();
        for (name, raw) in &payloads {
            let octx = OpCtx {
                dtype: Datatype::F32,
                extent: &[raw.len() as u64 / 4],
            };
            let framed =
                ops::encode_bytes(&chain, &octx, raw, &mut rep).unwrap();
            let back = ops::decode_bytes(&chain, &octx, &framed,
                                         raw.len(), &mut rep)
                .unwrap();
            if chain.is_lossless() {
                assert_eq!(*back, *raw, "{spec} not lossless on {name}");
            }
        }
        if spec == "shuffle|rle" {
            shuffle_rle_ratio = rep.ratio();
        }
        t.row(vec![
            spec.into(),
            format!("{:.2}x", rep.ratio()),
            fmt_bytes(rep.bytes_saved().max(0) as u64),
            fmt_rate(rep.encode_rate()),
            fmt_rate(rep.decode_rate()),
        ]);
    }

    // delta over monotone u64 index data (particle ids / offsets).
    let ids: Vec<u64> =
        (0..particles as u64).map(|i| 5_000_000 + i * 3).collect();
    let raw = cast::u64_to_bytes(&ids);
    for spec in ["delta", "delta|rle"] {
        let chain = OpChain::parse(spec).unwrap();
        let mut rep = OpsReport::default();
        let octx = OpCtx {
            dtype: Datatype::U64,
            extent: &[ids.len() as u64],
        };
        let framed =
            ops::encode_bytes(&chain, &octx, &raw, &mut rep).unwrap();
        let back = ops::decode_bytes(&chain, &octx, &framed, raw.len(),
                                     &mut rep)
            .unwrap();
        assert_eq!(*back, *raw, "{spec} not lossless on u64 ids");
        t.row(vec![
            format!("{spec} (u64 ids)"),
            format!("{:.2}x", rep.ratio()),
            fmt_bytes(rep.bytes_saved().max(0) as u64),
            fmt_rate(rep.encode_rate()),
            fmt_rate(rep.decode_rate()),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("fig_compression_micro").ok();

    assert!(
        shuffle_rle_ratio > 1.5,
        "ACCEPTANCE: shuffle|rle ratio {shuffle_rle_ratio:.2} <= 1.5"
    );
    json.gauge("shuffle_rle_ratio", shuffle_rle_ratio, true);
    println!(
        "\nacceptance: shuffle|rle ratio {shuffle_rle_ratio:.2}x > 1.5x \
         on the producer's fields — OK"
    );
}

/// Stream `steps` producer steps over SST-TCP with `chain`, read every
/// variable whole, and return (raw bytes, wire bytes, wall seconds,
/// concatenated output) for comparison across chains.
fn stream_once(
    chain: &OpChain,
    steps: u64,
    particles: usize,
) -> (u64, u64, f64, Vec<u8>) {
    let mut writer = SstWriter::open(SstWriterOptions {
        listen: String::new(),
        transport: "tcp".into(),
        rank: 0,
        hostname: "bench".into(),
        queue: QueueConfig {
            policy: QueueFullPolicy::Block,
            limit: steps as usize + 2,
        },
        ..Default::default()
    })
    .unwrap();
    let addr = writer.address();
    let chain_w = chain.clone();
    let producer_thread = std::thread::spawn(move || {
        let mut p =
            SyntheticProducer::new(0, particles, 0, particles as u64,
                                   SEED)
                .with_ops(chain_w);
        for _ in 0..steps {
            assert_eq!(p.write_step(&mut writer).unwrap(),
                       StepStatus::Ok);
        }
        writer.close().unwrap();
    });

    let mut reader = SstReader::open(SstReaderOptions {
        writers: vec![addr],
        transport: "tcp".into(),
        begin_step_timeout: Duration::from_secs(60),
        ..Default::default()
    })
    .unwrap();

    let started = Instant::now();
    let mut raw_bytes = 0u64;
    let mut output = Vec::new();
    let mut seen = 0u64;
    while seen < steps {
        match reader.begin_step().unwrap() {
            StepStatus::Ok => {}
            StepStatus::NotReady => {
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            other => panic!("stream ended early: {other:?}"),
        }
        for var in reader.available_variables() {
            let data = reader
                .get(&var.name, Chunk::whole(var.shape.clone()))
                .unwrap();
            raw_bytes += data.len() as u64;
            output.extend_from_slice(&data);
        }
        reader.end_step().unwrap();
        seen += 1;
    }
    let wall = started.elapsed().as_secs_f64().max(1e-9);
    let wire_bytes = reader.stats().bytes_got;
    reader.close().unwrap();
    producer_thread.join().unwrap();
    (raw_bytes, wire_bytes, wall, output)
}

fn end_to_end_sst_tcp(smoke: bool, json: &mut BenchJson) {
    let steps: u64 = if smoke { 2 } else { 4 };
    let particles: usize = if smoke { 1 << 12 } else { 1 << 16 };

    let mut t = Table::new(
        "fig_compression 2: end-to-end over SST-TCP (whole-variable \
         reads, one reader)",
        &["chain", "raw", "wire", "wire ratio", "wall", "effective"],
    );

    let mut identity_output: Option<Vec<u8>> = None;
    for spec in ["identity", "shuffle|rle", "zfp:14|shuffle|rle"] {
        let chain = OpChain::parse(spec).unwrap();
        let (raw, wire, wall, output) =
            stream_once(&chain, steps, particles);
        match identity_output.take() {
            None => identity_output = Some(output),
            Some(want) => {
                if chain.is_lossless() {
                    assert_eq!(
                        output, want,
                        "ACCEPTANCE: {spec} end-to-end output differs \
                         from the identity chain"
                    );
                }
                identity_output = Some(want);
            }
        }
        if spec == "shuffle|rle" {
            json.gauge("e2e_shuffle_rle_wire_ratio",
                       raw as f64 / wire.max(1) as f64, true);
            json.info("e2e_shuffle_rle_bytes_per_s", raw as f64 / wall);
        }
        if spec == "identity" {
            json.info("e2e_identity_bytes_per_s", raw as f64 / wall);
        }
        t.row(vec![
            spec.into(),
            fmt_bytes(raw),
            fmt_bytes(wire),
            format!("{:.2}x", raw as f64 / wire.max(1) as f64),
            format!("{:.1} ms", wall * 1e3),
            fmt_rate(raw as f64 / wall),
        ]);
    }
    print!("\n{}", t.render());
    t.save_csv("fig_compression_e2e").ok();
    println!(
        "\nacceptance: lossless chains byte-identical to identity over \
         real SST-TCP — OK (the conformance suite proves the same for \
         bp, json and sst-inproc)"
    );
}

fn main() {
    let args = Args::from_env(false).unwrap_or_default();
    let smoke = smoke_mode(&args, "FIGC_SMOKE");
    let mut json = BenchJson::new("compression");
    codec_micro(smoke, &mut json);
    end_to_end_sst_tcp(smoke, &mut json);
    match json.save() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => println!("\nBENCH_compression.json not written: {e}"),
    }
}
