//! Quickstart: write and read an openPMD series, file-based and
//! streaming, with the *same* application code — the paper's
//! *reusability* property in ~100 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use anyhow::Result;

use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
use openpmd_stream::adios::engine::{cast, Engine, StepStatus};
use openpmd_stream::adios::sst::{
    SstReader, SstReaderOptions, SstWriter, SstWriterOptions,
};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::record::ParticleSpecies;
use openpmd_stream::openpmd::series::{Iteration, Series};

/// Write three iterations of a tiny particle species through ANY engine
/// — the code cannot tell whether it is writing a file or a stream.
fn write_series(engine: &mut dyn Engine) -> Result<()> {
    let mut series = Series::new("quickstart author",
                                 "openpmd-stream quickstart");
    let n = 256u64;
    for step in 0..3u64 {
        let mut it = Iteration::new(step as f64 * 0.05, 0.05);
        let mut species = ParticleSpecies::pic_layout(n);
        let chunk = Chunk::whole(vec![n]);
        for record in ["position", "momentum"] {
            let rec = species.records.get_mut(record).unwrap();
            for comp in ["x", "y", "z"] {
                let data: Vec<f32> = (0..n)
                    .map(|i| (step * 1000 + i) as f32 * 0.001)
                    .collect();
                rec.component_mut(comp)
                    .unwrap()
                    .store_chunk(chunk.clone(), cast::f32_to_bytes(&data))
                    .map_err(|e| anyhow::anyhow!(e))?;
            }
        }
        species
            .records
            .get_mut("weighting")
            .unwrap()
            .components
            .values_mut()
            .next()
            .unwrap()
            .store_chunk(chunk.clone(),
                         cast::f32_to_bytes(&vec![1.0; n as usize]))
            .map_err(|e| anyhow::anyhow!(e))?;
        it.particles.insert("e".into(), species);
        series.write_iteration(engine, step, &mut it)?;
    }
    engine.close()?;
    Ok(())
}

/// Read a series back through ANY engine and summarize it.
fn read_series(engine: &mut dyn Engine, label: &str) -> Result<()> {
    loop {
        let (status, parsed) = Series::read_iteration(engine)?;
        match status {
            StepStatus::Ok => {}
            StepStatus::EndOfStream => break,
            _ => continue,
        }
        let (index, it) = parsed.unwrap();
        let species = &it.particles["e"];
        let pos_x = openpmd_stream::openpmd::series::var_name(
            index, "e", "position", "x");
        let chunks = engine.available_chunks(&pos_x);
        let n = species.records["position"].components["x"]
            .dataset
            .extent[0];
        // Two-phase read: defer, perform, take. (`engine.get(..)` is the
        // eager shorthand for exactly this sequence.)
        let handle = engine.get_deferred(&pos_x, Chunk::whole(vec![n]))?;
        engine.perform_gets()?;
        let data = cast::bytes_to_f32(&engine.take_get(handle)?)?;
        println!(
            "  [{label}] iteration {index}: t={:.3}, {} particles, \
             {} written chunk(s), position/x[0..3] = {:?}",
            it.time,
            n,
            chunks.len(),
            &data[..3]
        );
        engine.end_step()?;
    }
    engine.close()?;
    Ok(())
}

fn main() -> Result<()> {
    // --- File-based: BP engine ---------------------------------------
    let path = std::env::temp_dir()
        .join(format!("quickstart-{}.bp", std::process::id()));
    println!("1. writing BP file {} ...", path.display());
    let mut writer = BpWriter::create(&path, WriterCtx {
        rank: 0,
        hostname: "quickstart".into(),
    })?;
    write_series(&mut writer)?;
    println!("2. reading it back ...");
    let mut reader = BpReader::open(&path)?;
    read_series(&mut reader, "bp")?;

    // --- Streaming: SST engine, same functions -----------------------
    println!("3. same code over an SST stream (writer thread + reader) ...");
    let writer = SstWriter::open(SstWriterOptions {
        listen: format!("quickstart-{}", std::process::id()),
        // Block (not Discard): this demo wants every step delivered even
        // if the reader subscribes late.
        queue: openpmd_stream::adios::sst::QueueConfig {
            policy: openpmd_stream::adios::sst::QueueFullPolicy::Block,
            limit: 8,
        },
        ..Default::default()
    })?;
    let addr = writer.address();
    let writer_thread = std::thread::spawn(move || -> Result<()> {
        let mut writer = writer;
        write_series(&mut writer)
    });
    let mut reader = SstReader::open(SstReaderOptions {
        writers: vec![addr],
        ..Default::default()
    })?;
    read_series(&mut reader, "sst")?;
    writer_thread.join().unwrap()?;

    // --- Conformance check -------------------------------------------
    let mut reader = BpReader::open(&path)?;
    let (_, parsed) = Series::read_iteration(&mut reader)?;
    let (index, it) = parsed.unwrap();
    let findings =
        openpmd_stream::openpmd::validate::validate_iteration(index, &it);
    println!(
        "4. openPMD conformance: {} ({} findings)",
        if openpmd_stream::openpmd::validate::is_conformant(&findings) {
            "OK"
        } else {
            "FAILED"
        },
        findings.len()
    );

    std::fs::remove_file(&path).ok();
    let _unused: openpmd_stream::adios::engine::Bytes =
        Arc::new(Vec::new());
    println!("quickstart done.");
    Ok(())
}
