//! Explore the §3 chunk-distribution strategies on synthetic chunk
//! tables and see the locality / balancing / alignment trade-offs the
//! paper discusses — without running any IO.
//!
//! ```bash
//! cargo run --release --example distribution_playground \
//!     [-- --nodes 8 --writers-per-node 3 --readers-per-node 3 \
//!         --jitter 0.1]
//! ```

use anyhow::Result;

use openpmd_stream::bench::Table;
use openpmd_stream::cluster::topology::{ClusterLayout, Placement};
use openpmd_stream::distribution::{
    by_name, metrics, verify_complete, ChunkTable,
};
use openpmd_stream::openpmd::chunk::{Chunk, WrittenChunkInfo};
use openpmd_stream::util::cli::Args;
use openpmd_stream::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(false)?;
    let nodes: usize = args.get_parse_or("nodes", 8)?;
    let wpn: usize = args.get_parse_or("writers-per-node", 3)?;
    let rpn: usize = args.get_parse_or("readers-per-node", 3)?;
    let jitter: f64 = args.get_parse_or("jitter", 0.10)?;
    let chunk_elems: u64 = args.get_parse_or("chunk-elems", 1_000_000)?;

    let cluster = ClusterLayout { nodes, gpus_per_node: wpn + rpn };
    let placement = Placement::co_scheduled(cluster, wpn, rpn);
    let readers = placement.reader_layout();

    // Jittered contiguous chunk table, shuffled arrival order (as an
    // ADIOS metadata table would be).
    let mut rng = Rng::new(2021);
    let mut chunks = Vec::new();
    let mut off = 0u64;
    for w in &placement.writers {
        let size = (chunk_elems as f64
            * (1.0 + jitter * (2.0 * rng.f64() - 1.0))) as u64;
        chunks.push(WrittenChunkInfo::new(
            Chunk::new(vec![off], vec![size]),
            w.rank,
            w.hostname.clone(),
        ));
        off += size;
    }
    rng.shuffle(&mut chunks);
    let table = ChunkTable { dataset_extent: vec![off], chunks };

    println!(
        "{} writers on {} nodes -> {} readers ({} chunks, jitter +-{:.0}%)\n",
        placement.writers.len(),
        nodes,
        readers.len(),
        table.chunks.len(),
        jitter * 100.0
    );

    let mut t = Table::new(
        "distribution strategy properties (SS 3.1)",
        &["strategy", "balance (max/ideal)", "locality", "alignment",
          "mean partners", "max partners", "slices"],
    );
    for name in ["roundrobin", "hyperslabs", "binpacking", "hostname",
                 "hostname:roundrobin:hyperslabs"] {
        let strategy = by_name(name)?;
        let assignment = strategy.distribute(&table, &readers);
        verify_complete(&table, &assignment)
            .map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
        let q = metrics::quality(&table, &readers, &assignment);
        t.row(vec![
            name.into(),
            format!("{:.3}", q.balance_factor),
            format!("{:>5.1}%", q.locality_fraction * 100.0),
            format!("{:.3}", q.alignment),
            format!("{:.2}", q.mean_partners),
            format!("{}", q.max_partners),
            format!("{}", assignment.total_slices()),
        ]);
    }
    print!("{}", t.render());
    println!("\nevery strategy passed the completeness check \
              (each written element assigned exactly once).");

    // The binpacking 2x guarantee, empirically.
    let bp = by_name("binpacking")?.distribute(&table, &readers);
    // The guarantee is against the *integral* ideal (ceil), which is
    // what the Next-Fit bins are sized by.
    let ideal = table.total_elements().div_ceil(readers.len() as u64);
    let worst_load = readers
        .ranks
        .iter()
        .map(|r| bp.elements_for(r.rank))
        .max()
        .unwrap();
    println!(
        "binpacking worst reader load: {:.3}x ideal \
         (guarantee: <= 2.0x)",
        worst_load as f64 / ideal as f64
    );
    assert!(worst_load <= 2 * ideal);
    Ok(())
}
