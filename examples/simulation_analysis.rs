//! END-TO-END driver (the §4.2 pipeline, all three layers composing):
//!
//! ```text
//!   KhProducer ranks (L2/L1: pic_step artifact via PJRT)
//!        | openPMD iterations over SST (L3, real engine, real threads)
//!        v
//!   chunk-distribution strategy (§3) decides who loads what
//!        |
//!        v
//!   SaxsAnalyzer ranks (L2/L1: saxs artifact via PJRT)
//!        -> accumulated I(q) scatter plot (CSV) + energy spectrum
//! ```
//!
//! This is the workload the paper's §4.2 runs at 512 nodes with
//! PIConGPU + GAPD; here it runs 2 producer + 2 analysis ranks with
//! ~100k macroparticles, proving that artifacts, streaming engines,
//! distribution strategies and analyses compose. The run is recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example simulation_analysis \
//!     [-- --particles 100000 --outputs 4 --strategy hyperslabs \
//!         --transport inproc --no-runtime]
//! ```

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use openpmd_stream::adios::engine::{cast, Engine, StepStatus};
use openpmd_stream::adios::sst::{
    QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions, SstWriter,
    SstWriterOptions, WriterGroup,
};
use openpmd_stream::analysis::{EnergySpectrum, SaxsAnalyzer};
use openpmd_stream::distribution::{self, ChunkTable, ReaderLayout};
use openpmd_stream::openpmd::series::{var_name, Series};
use openpmd_stream::openpmd::record::SCALAR;
use openpmd_stream::pipeline::metrics::{OpKind, PerceivedThroughput};
use openpmd_stream::producer::KhProducer;
use openpmd_stream::runtime::Runtime;
use openpmd_stream::util::bytes::{fmt_bytes, fmt_rate};
use openpmd_stream::util::cli::Args;

const WRITERS: usize = 2;
const READERS: usize = 2;

fn main() -> Result<()> {
    let t_start = Instant::now();
    let args = Args::from_env(false)?;
    let particles: usize = args.get_parse_or("particles", 100_000)?;
    let outputs: u64 = args.get_parse_or("outputs", 4)?;
    let period: u64 = args.get_parse_or("period", 5)?;
    let strategy_name =
        args.get_or("strategy", "hyperslabs").to_string();
    let transport = args.get_or("transport", "inproc").to_string();

    // PJRT executables are not Send (the xla crate uses Rc internally),
    // so every thread loads its own Runtime — mirroring real deployments
    // where each rank owns its PJRT client.
    let use_runtime = !args.flag("no-runtime")
        && match Runtime::load_default() {
            Ok(rt) => {
                println!("PJRT runtime up: artifacts {:?}", rt.names());
                true
            }
            Err(e) => {
                println!(
                    "artifacts unavailable ({e:#}); using rust fallbacks"
                );
                false
            }
        };

    println!(
        "simulation_analysis: {WRITERS} KH producers x {particles} \
         particles --SST({transport})--> {READERS} SAXS ranks, strategy \
         {strategy_name}, {outputs} outputs every {period} PIC steps"
    );

    // --- SST writers, one per producer rank --------------------------
    let group = WriterGroup::new();
    let mut writers = Vec::new();
    let mut addrs = Vec::new();
    for rank in 0..WRITERS {
        let w = SstWriter::open(SstWriterOptions {
            listen: if transport == "inproc" {
                format!("simana-{rank}-{}", std::process::id())
            } else {
                String::new()
            },
            transport: transport.clone(),
            rank,
            hostname: "node0000".into(),
            queue: QueueConfig { policy: QueueFullPolicy::Block, limit: 2 },
            group: Some(group.clone()),
            ..Default::default()
        })?;
        addrs.push(w.address());
        writers.push(w);
    }

    // --- Producer threads (L3 driving L2/L1 through PJRT) ------------
    let per_rank = particles / WRITERS;
    let producer_threads: Vec<_> = writers
        .into_iter()
        .enumerate()
        .map(|(rank, mut engine)| {
            std::thread::spawn(move || -> Result<f64> {
                let runtime = if use_runtime {
                    Some(Runtime::load_default()?)
                } else {
                    None
                };
                let mut producer = KhProducer::new(
                    rank,
                    "node0000",
                    per_rank,
                    (rank * per_rank) as u64,
                    (per_rank * WRITERS) as u64,
                    7,
                    runtime.as_ref(),
                )?;
                let mut series =
                    Series::new("simulation_analysis", "openpmd-stream");
                let mut compute_s = 0.0;
                for out in 0..outputs {
                    let t0 = Instant::now();
                    for _ in 0..period {
                        producer.step()?;
                    }
                    compute_s += t0.elapsed().as_secs_f64();
                    let status = producer.write_iteration(
                        &mut series, &mut engine, out)?;
                    if status != StepStatus::Ok {
                        bail!("unexpected producer status {status:?}");
                    }
                }
                engine.close()?;
                Ok(compute_s)
            })
        })
        .collect();

    // --- Analysis threads (readers; distribution decides the loads) --
    let reader_layout = ReaderLayout::local(READERS).unwrap();
    let analysis_threads: Vec<_> = (0..READERS)
        .map(|rank| {
            let addrs = addrs.clone();
            let strategy_name = strategy_name.clone();
            let layout = reader_layout.clone();
            let transport = transport.clone();
            // PJRT handles are not Send: return plain accumulators.
            std::thread::spawn(move || -> Result<(
                Vec<f64>,
                u64,
                Vec<f64>,
                u64,
                PerceivedThroughput,
            )> {
                let runtime = if use_runtime {
                    Some(Runtime::load_default()?)
                } else {
                    None
                };
                let strategy = distribution::by_name(&strategy_name)?;
                let mut reader = SstReader::open(SstReaderOptions {
                    writers: addrs,
                    transport,
                    rank,
                    hostname: "node0000".into(),
                    begin_step_timeout: Duration::from_secs(120),
                    codecs: None,
                })?;
                let mut saxs = SaxsAnalyzer::new(2.0, runtime.as_ref())?;
                let mut spectrum =
                    EnergySpectrum::new(runtime.as_ref())?;
                let mut metrics = PerceivedThroughput::new();
                let mut step_idx = 0u64;
                loop {
                    match reader.begin_step()? {
                        StepStatus::Ok => {}
                        StepStatus::EndOfStream => break,
                        _ => continue,
                    }
                    // The §3 machinery: distribute this step's chunks.
                    let vars = reader.available_variables();
                    let Some(wvar) = vars
                        .iter()
                        .find(|v| v.name.ends_with("/weighting"))
                    else {
                        bail!("no weighting record in step");
                    };
                    let index = openpmd_stream::openpmd::series::
                        parse_var_name(&wvar.name)?.index;
                    let table = ChunkTable {
                        dataset_extent: wvar.shape.clone(),
                        chunks: reader.available_chunks(&wvar.name),
                    };
                    let assignment = strategy.distribute(&table, &layout);
                    let mut pos = Vec::new();
                    let mut mom = Vec::new();
                    let mut wts = Vec::new();
                    for slice in assignment.slices(rank) {
                        let sel = slice.chunk.clone();
                        let t = metrics.start(OpKind::Load, step_idx, rank);
                        // Two-phase: defer all seven component loads,
                        // perform them as ONE batched exchange per
                        // owning writer, then redeem.
                        let mut handles = Vec::new();
                        for record in ["position", "momentum"] {
                            for comp in ["x", "y", "z"] {
                                let name =
                                    var_name(index, "e", record, comp);
                                handles.push(reader.get_deferred(
                                    &name, sel.clone())?);
                            }
                        }
                        let hw = reader.get_deferred(
                            &var_name(index, "e", "weighting", SCALAR),
                            sel.clone(),
                        )?;
                        reader.perform_gets()?;
                        let mut bytes = 0u64;
                        let mut cols = Vec::new();
                        for h in handles {
                            let data = reader.take_get(h)?;
                            bytes += data.len() as u64;
                            cols.push(cast::bytes_to_f32(&data)?);
                        }
                        let w = reader.take_get(hw)?;
                        bytes += w.len() as u64;
                        metrics.finish(t, bytes);
                        let n = sel.num_elements() as usize;
                        for i in 0..n {
                            pos.extend_from_slice(&[
                                cols[0][i], cols[1][i], cols[2][i],
                            ]);
                            mom.extend_from_slice(&[
                                cols[3][i], cols[4][i], cols[5][i],
                            ]);
                        }
                        wts.extend_from_slice(&cast::bytes_to_f32(&w)?);
                    }
                    // L1/L2 compute through PJRT.
                    saxs.consume(&pos, &wts)?;
                    spectrum.consume(&mom, &wts)?;
                    reader.end_step()?;
                    step_idx += 1;
                }
                reader.close()?;
                Ok((
                    saxs.pattern().to_vec(),
                    saxs.atoms_seen,
                    spectrum.spectrum().to_vec(),
                    spectrum.samples_seen,
                    metrics,
                ))
            })
        })
        .collect();

    let mut compute_total = 0.0;
    for t in producer_threads {
        compute_total += t.join().unwrap()?;
    }
    let mut saxs = SaxsAnalyzer::new(2.0, None)?;
    let mut spectrum = EnergySpectrum::new(None)?;
    let mut metrics = PerceivedThroughput::new();
    for t in analysis_threads {
        let (pattern, atoms, bins, samples, m) = t.join().unwrap()?;
        saxs.absorb_pattern(&pattern, atoms, 0);
        spectrum.absorb_bins(&bins, samples);
        metrics.absorb(m);
    }

    // --- Results -------------------------------------------------------
    let loads = metrics.report(OpKind::Load, READERS);
    let csv = "scatter_plot.csv";
    saxs.write_csv(csv)?;
    let expected =
        (per_rank * WRITERS) as u64 * outputs;
    println!("macroparticles analyzed:  {} (expected {expected})",
             saxs.atoms_seen);
    assert_eq!(saxs.atoms_seen, expected, "lost particles in the pipeline");
    assert_eq!(spectrum.samples_seen, expected);
    let total_w = spectrum.total_weight();
    let rel = (total_w - expected as f64).abs() / (expected as f64);
    assert!(rel < 1e-6, "weight not conserved: {total_w}");
    println!("energy spectrum weight:   {total_w:.1} (conserved)");
    println!("peak I(q):                {:.3e}",
             saxs.pattern().iter().cloned().fold(0.0, f64::max));
    println!("scatter plot:             {csv} ({} q-points)",
             saxs.pattern().len());
    println!("streamed:                 {} in {} load ops",
             fmt_bytes(loads.total_bytes), loads.ops);
    println!("perceived load rate:      {} per reader, {} aggregate",
             fmt_rate(loads.mean_instance_rate),
             fmt_rate(loads.aggregate_rate));
    println!("load times:               {}", loads.times.render());
    println!("producer compute total:   {compute_total:.2}s across \
              {WRITERS} ranks");
    println!("wall time:                {:.2}s", t_start.elapsed()
             .as_secs_f64());
    println!("simulation_analysis done (all three layers composed).");
    Ok(())
}
