//! The paper's §4.1 setup, for real (laptop scale): N producers stream
//! to one `openpmd-pipe` per "node", which writes an aggregated BP file
//! — streaming as asynchronous, node-aggregating IO (Fig. 5).
//!
//! Producers are synthetic (data shape of PIConGPU, no physics) so the
//! example exercises the *IO* path at meaningful sizes. Every role runs
//! on its own thread with its own engines; swap the transport to "tcp"
//! and the roles can be separate processes.
//!
//! ```bash
//! cargo run --release --example streaming_pipeline [-- --producers 6 \
//!     --steps 5 --mib-per-producer 64 --transport inproc]
//! ```

use std::time::Duration;

use anyhow::Result;

use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
use openpmd_stream::adios::engine::{Engine, StepStatus};
use openpmd_stream::adios::sst::{
    QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions, SstWriter,
    SstWriterOptions, WriterGroup,
};
use openpmd_stream::pipeline::metrics::OpKind;
use openpmd_stream::pipeline::pipe::{run_pipe, PipeOptions};
use openpmd_stream::producer::SyntheticProducer;
use openpmd_stream::util::bytes::{fmt_bytes, fmt_rate, MIB};
use openpmd_stream::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false)?;
    let producers: usize = args.get_parse_or("producers", 6)?;
    let steps: u64 = args.get_parse_or("steps", 5)?;
    let mib: u64 = args.get_parse_or("mib-per-producer", 64)?;
    let transport = args.get_or("transport", "inproc").to_string();
    let compute_ms: u64 = args.get_parse_or("compute-ms", 150)?;
    let bytes_per_producer = mib * MIB;

    println!(
        "streaming_pipeline: {producers} producers x {} x {steps} steps \
         --SST({transport})--> openpmd-pipe --> BP file",
        fmt_bytes(bytes_per_producer)
    );

    // --- Writers (one per producer rank, shared discard group) -------
    let group = WriterGroup::new();
    let mut writer_engines = Vec::new();
    let mut addrs = Vec::new();
    for rank in 0..producers {
        let w = SstWriter::open(SstWriterOptions {
            listen: if transport == "inproc" {
                format!("pipe-demo-{rank}-{}", std::process::id())
            } else {
                String::new()
            },
            transport: transport.clone(),
            rank,
            hostname: "node0000".into(),
            queue: QueueConfig { policy: QueueFullPolicy::Discard,
                                 limit: 2 },
            group: Some(group.clone()),
            ..Default::default()
        })?;
        addrs.push(w.address());
        writer_engines.push(w);
    }

    // --- The pipe (reader side of the stream, writer of the file) ----
    let bp_path = std::env::temp_dir()
        .join(format!("pipeline-{}.bp", std::process::id()));
    let pipe_thread = {
        let addrs = addrs.clone();
        let bp_path = bp_path.clone();
        let transport = transport.clone();
        std::thread::spawn(move || -> Result<_> {
            let mut input = SstReader::open(SstReaderOptions {
                writers: addrs,
                transport,
                rank: 0,
                hostname: "node0000".into(),
                begin_step_timeout: Duration::from_secs(30),
                codecs: None,
            })?;
            let mut output = BpWriter::create(&bp_path, WriterCtx {
                rank: 0,
                hostname: "node0000".into(),
            })?;
            let report = run_pipe(&mut input, &mut output,
                                  PipeOptions::solo())?;
            Ok(report)
        })
    };

    // --- Producers ----------------------------------------------------
    let producer_threads: Vec<_> = writer_engines
        .into_iter()
        .enumerate()
        .map(|(rank, mut engine)| {
            let total_ranks = producers;
            std::thread::spawn(move || -> Result<(u64, u64)> {
                let mut p = SyntheticProducer::with_bytes_per_step(
                    rank, mib * MIB, total_ranks, 42);
                let mut written = 0;
                let mut discarded = 0;
                for _ in 0..steps {
                    // Simulated compute phase between outputs — the
                    // pacing that lets streaming IO hide behind it
                    // (SS 4.1). Shrink --compute-ms to watch the
                    // QueueFullPolicy start discarding.
                    std::thread::sleep(Duration::from_millis(compute_ms));
                    match p.write_step(&mut engine)? {
                        StepStatus::Ok => written += 1,
                        StepStatus::Discarded => discarded += 1,
                        other => anyhow::bail!("unexpected {other:?}"),
                    }
                }
                engine.close()?;
                Ok((written, discarded))
            })
        })
        .collect();

    let mut written = 0;
    let mut discarded = 0;
    for t in producer_threads {
        let (w, d) = t.join().unwrap()?;
        written += w;
        discarded += d;
    }
    let report = pipe_thread.join().unwrap()?;

    // --- Report (the §4.1 metrics, measured not simulated) -----------
    let loads = report.metrics.report(OpKind::Load, producers);
    println!("producer steps written:   {written} (+{discarded} discarded)");
    println!("pipe steps forwarded:     {}", report.steps);
    println!("pipe bytes in -> out:     {} -> {}",
             fmt_bytes(report.bytes_in), fmt_bytes(report.bytes_out));
    println!("perceived load rate:      {} per instance, {} aggregate",
             fmt_rate(loads.mean_instance_rate),
             fmt_rate(loads.aggregate_rate));
    println!("load times:               {}", loads.times.render());

    // --- Verify the aggregated file -----------------------------------
    let mut check = BpReader::open(&bp_path)?;
    let mut file_steps = 0;
    while check.begin_step()? == StepStatus::Ok {
        let vars = check.available_variables();
        assert_eq!(vars.len(), 7, "expected 7 particle components");
        // Node-level aggregation: all producers' chunks in one file.
        let chunks = check.available_chunks(&vars[0].name);
        assert_eq!(chunks.len(), producers);
        check.end_step()?;
        file_steps += 1;
    }
    println!("aggregated BP file:       {} steps, {} ({})",
             file_steps,
             fmt_bytes(std::fs::metadata(&bp_path)?.len()),
             bp_path.display());
    assert_eq!(file_steps as u64, report.steps);
    std::fs::remove_file(&bp_path).ok();
    println!("streaming_pipeline done.");
    Ok(())
}
